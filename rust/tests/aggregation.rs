//! Stage-0 aggregation conformance: determinism across threads and
//! backends, the ε = 0 bitwise pin, degenerate corpora, and the
//! full-corpus label guarantee.
//!
//! The fixture of choice is a *duplicated* corpus — every segment
//! appears twice — because it makes the leader pass provable: exact
//! duplicates sit at DTW distance 0, every distinct pair sits at ≥ the
//! corpus's smallest nonzero distance, so with ε strictly between the
//! two each duplicate must join its original's group and nothing else
//! merges.

mod common;

use mahc::aggregate::aggregate;
use mahc::config::{AggregateConfig, AlgoConfig, Convergence, DatasetSpec, StreamConfig};
use mahc::corpus::{generate, Segment, SegmentSet};
use mahc::distance::{build_condensed, BlockedBackend, DtwBackend, NativeBackend};
use mahc::mahc::{MahcDriver, StreamingDriver};

/// A corpus where segment `n + i` is an exact copy of segment `i`.
fn duplicated_corpus(n: usize, classes: usize, seed: u64) -> SegmentSet {
    let base = generate(&DatasetSpec::tiny(n, classes, seed));
    let mut segments = base.segments.clone();
    for i in 0..n {
        let mut dup = base.segments[i].clone();
        dup.id = n + i;
        segments.push(dup);
    }
    let set = SegmentSet {
        name: format!("{}_doubled", base.name),
        dim: base.dim,
        segments,
        num_classes: base.num_classes,
    };
    set.validate().expect("duplicated corpus is well-formed");
    set
}

/// Half the smallest nonzero pair distance: duplicates (distance 0)
/// merge, distinct segments (distance ≥ 2ε) never do.
fn below_min_nonzero_distance(set: &SegmentSet) -> f32 {
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let cond = build_condensed(&refs, &NativeBackend::new(), 4).unwrap();
    let min_nonzero = cond
        .as_slice()
        .iter()
        .copied()
        .filter(|&d| d > 0.0)
        .fold(f32::INFINITY, f32::min);
    assert!(min_nonzero.is_finite() && min_nonzero > 0.0);
    min_nonzero * 0.5
}

fn cfg(eps: f32) -> AlgoConfig {
    AlgoConfig {
        p0: 3,
        beta: Some(40),
        convergence: Convergence::FixedIters(3),
        aggregate: AggregateConfig::new(eps),
        ..Default::default()
    }
}

#[test]
fn duplicates_collapse_onto_their_originals() {
    let n = 60;
    let set = duplicated_corpus(n, 5, 201);
    let eps = below_min_nonzero_distance(&set);
    let agg = aggregate(
        &set,
        &AggregateConfig::new(eps),
        &NativeBackend::new(),
        None,
    )
    .unwrap();
    // Every duplicate shares its original's representative; only
    // zero-distance pairs merged, so at most the originals remain.
    assert!(agg.reps() <= n, "{} reps > {n} originals", agg.reps());
    assert!(agg.compression_ratio() <= 0.5);
    for i in 0..n {
        assert_eq!(
            agg.rep_of[i],
            agg.rep_of[n + i],
            "duplicate {i} strayed from its original's group"
        );
    }

    // End to end: the aggregated run labels all 2n segments, gives
    // duplicate pairs identical labels, and stays close to the
    // unaggregated run's quality.
    let plain = MahcDriver::new(&set, cfg(0.0), &NativeBackend::new())
        .unwrap()
        .run()
        .unwrap();
    let res = MahcDriver::new(&set, cfg(eps), &NativeBackend::new())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(res.labels.len(), 2 * n);
    assert!(res.labels.iter().all(|&l| l < res.k));
    for i in 0..n {
        assert_eq!(
            res.labels[i],
            res.labels[n + i],
            "duplicate {i} labelled apart from its original"
        );
    }
    assert!(
        res.f_measure > plain.f_measure - 0.1,
        "aggregated F {:.3} fell too far under plain {:.3}",
        res.f_measure,
        plain.f_measure
    );
    let r0 = &res.history.records[0];
    assert_eq!(r0.representatives, agg.reps());
    assert!(r0.compression_ratio <= 0.5);
    assert_eq!(r0.assignment_pairs, agg.probe_pairs);
}

#[test]
fn aggregation_is_invariant_to_threads_and_backend() {
    let set = duplicated_corpus(40, 4, 202);
    let eps = below_min_nonzero_distance(&set);
    let native = NativeBackend::new();
    let blocked = BlockedBackend::new();
    let backends: [(&str, &dyn DtwBackend); 2] = [("native", &native), ("blocked", &blocked)];

    let reference = aggregate(&set, &AggregateConfig::new(eps), &native, None).unwrap();
    let mut runs = Vec::new();
    for (bname, backend) in backends {
        let a = aggregate(&set, &AggregateConfig::new(eps), backend, None).unwrap();
        assert_eq!(a.rep_ids, reference.rep_ids, "{bname}: rep set diverged");
        assert_eq!(a.members, reference.members, "{bname}: memberships diverged");
        assert_eq!(a.rep_of, reference.rep_of, "{bname}");
        assert_eq!(a.probe_pairs, reference.probe_pairs, "{bname}");
        // Built-in sweep plus this CI matrix cell's MAHC_TEST_THREADS.
        for threads in common::thread_matrix(&[1, 8]) {
            let mut c = cfg(eps);
            c.threads = threads;
            let res = MahcDriver::new(&set, c, backend).unwrap().run().unwrap();
            runs.push((format!("{bname}/t{threads}"), res));
        }
    }
    let (ref_name, ref_run) = &runs[0];
    for (name, run) in &runs[1..] {
        assert_eq!(
            run.labels, ref_run.labels,
            "{name} labels diverged from {ref_name}"
        );
        assert_eq!(run.k, ref_run.k, "{name}");
        assert_eq!(
            run.f_measure.to_bits(),
            ref_run.f_measure.to_bits(),
            "{name}"
        );
    }
}

#[test]
fn epsilon_zero_batch_run_is_bitwise_the_unaggregated_run() {
    let set = generate(&DatasetSpec::tiny(90, 6, 203));
    let backend = NativeBackend::new();
    let mut plain_cfg = cfg(0.0);
    plain_cfg.aggregate = AggregateConfig::default();
    let mut zero_cfg = cfg(0.0);
    zero_cfg.aggregate.cap = Some(7); // cap without ε is inert
    let plain = MahcDriver::new(&set, plain_cfg, &backend)
        .unwrap()
        .run()
        .unwrap();
    let zero = MahcDriver::new(&set, zero_cfg, &backend)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(plain.labels, zero.labels);
    assert_eq!(plain.k, zero.k);
    assert_eq!(plain.f_measure.to_bits(), zero.f_measure.to_bits());
    assert_eq!(plain.history.algo, zero.history.algo);
    assert_eq!(plain.history.records.len(), zero.history.records.len());
    for (a, b) in plain.history.records.iter().zip(&zero.history.records) {
        assert_eq!(a.subsets, b.subsets);
        assert_eq!(a.max_occupancy, b.max_occupancy);
        assert_eq!(a.min_occupancy, b.min_occupancy);
        assert_eq!(a.max_occupancy_pre_split, b.max_occupancy_pre_split);
        assert_eq!(a.splits, b.splits);
        assert_eq!(a.total_clusters, b.total_clusters);
        assert_eq!(a.f_measure.to_bits(), b.f_measure.to_bits());
        assert_eq!(a.peak_matrix_bytes, b.peak_matrix_bytes);
        assert_eq!(a.cache, b.cache, "pair counters must match");
        assert_eq!(b.representatives, 0);
        assert_eq!(b.compression_ratio, 1.0);
        assert_eq!(b.assignment_pairs, 0);
    }
}

#[test]
fn aggregated_stream_labels_everyone_and_matches_plain_at_epsilon_zero() {
    let set = duplicated_corpus(45, 4, 204);
    let eps = below_min_nonzero_distance(&set);
    let backend = NativeBackend::new();

    // ε = 0, bitwise against the never-aggregated stream.
    let plain = StreamingDriver::new(
        &set,
        StreamConfig::new(
            AlgoConfig {
                aggregate: AggregateConfig::default(),
                ..cfg(0.0)
            },
            30,
        ),
        &backend,
    )
    .unwrap()
    .run()
    .unwrap();
    let zero = StreamingDriver::new(&set, StreamConfig::new(cfg(0.0), 30), &backend)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(plain.labels, zero.labels);
    assert_eq!(plain.k, zero.k);
    assert_eq!(plain.f_measure.to_bits(), zero.f_measure.to_bits());

    // ε > 0: the stream runs over representatives (duplicates halve
    // it), still labels all 90 segments, duplicates together.
    let agg = StreamingDriver::new(&set, StreamConfig::new(cfg(eps), 30), &backend)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(agg.labels.len(), 90);
    assert!(agg.labels.iter().all(|&l| l < agg.k));
    for i in 0..45 {
        assert_eq!(agg.labels[i], agg.labels[45 + i], "duplicate {i}");
    }
    let r0 = &agg.history.records[0];
    assert!(r0.representatives <= 45);
    assert!(r0.compression_ratio <= 0.5);
    assert!(r0.assignment_pairs > 0);
    // Fewer representatives than segments means fewer shards than the
    // plain stream of the same shard size.
    assert!(agg.shards <= plain.shards);
}

#[test]
fn cache_is_shared_between_leader_pass_and_stage1() {
    let set = duplicated_corpus(50, 4, 205);
    let eps = below_min_nonzero_distance(&set);
    let backend = NativeBackend::new();
    let plain = MahcDriver::new(&set, cfg(eps), &backend)
        .unwrap()
        .run()
        .unwrap();
    let mut cached_cfg = cfg(eps);
    cached_cfg.cache_bytes = 8 << 20;
    let cached = MahcDriver::new(&set, cached_cfg, &backend)
        .unwrap()
        .run()
        .unwrap();
    // The cache must not change a bit of the aggregated pipeline...
    assert_eq!(plain.labels, cached.labels);
    assert_eq!(plain.k, cached.k);
    assert_eq!(plain.f_measure.to_bits(), cached.f_measure.to_bits());
    // ...and stage 1 must reuse leader-pass probes: every (rep, rep)
    // pair was probed when the newer rep was admitted, so iteration 1's
    // condensed builds see warm pairs immediately.
    assert!(
        cached.history.records[0].cache.hits > 0,
        "stage 1 found no warm leader-pass pairs: {:?}",
        cached.history.records[0].cache
    );
}

#[test]
fn degenerate_corpora_are_pinned() {
    // All-identical segments: one group without a cap, ⌈n/cap⌉ groups
    // with one, and the driver runs cleanly on the collapsed corpus.
    let base = generate(&DatasetSpec::tiny(12, 2, 206));
    let proto = base.segments[0].clone();
    let n = 9;
    let identical = SegmentSet {
        name: "identical".into(),
        dim: base.dim,
        segments: (0..n)
            .map(|id| Segment {
                id,
                class_id: 0,
                len: proto.len,
                dim: proto.dim,
                feats: proto.feats.clone(),
            })
            .collect(),
        num_classes: 1,
    };
    identical.validate().unwrap();

    let free = aggregate(
        &identical,
        &AggregateConfig::new(0.5),
        &NativeBackend::new(),
        None,
    )
    .unwrap();
    assert_eq!(free.reps(), 1);
    assert_eq!(free.members[0].len(), n);

    let capped = aggregate(
        &identical,
        &AggregateConfig::new(0.5).with_cap(4),
        &NativeBackend::new(),
        None,
    )
    .unwrap();
    assert_eq!(capped.reps(), 3, "⌈9/4⌉ saturated groups");
    assert_eq!(
        capped.members.iter().map(Vec::len).collect::<Vec<_>>(),
        vec![4, 4, 1]
    );

    let mut c = cfg(0.5);
    c.p0 = 1;
    c.beta = None;
    let res = MahcDriver::new(&identical, c, &NativeBackend::new())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(res.labels, vec![0; n], "identical corpus is one cluster");
    assert_eq!(res.k, 1);
    assert_eq!(res.f_measure, 1.0, "single class, single cluster");

    // Single-segment corpus: aggregation is the identity and the run
    // still works.
    let single = SegmentSet {
        name: "single".into(),
        dim: proto.dim,
        segments: vec![Segment {
            id: 0,
            class_id: 0,
            len: proto.len,
            dim: proto.dim,
            feats: proto.feats.clone(),
        }],
        num_classes: 1,
    };
    let agg = aggregate(
        &single,
        &AggregateConfig::new(1.0),
        &NativeBackend::new(),
        None,
    )
    .unwrap();
    assert!(agg.is_identity());
    let mut c1 = cfg(1.0);
    c1.p0 = 1;
    c1.beta = None;
    let res = MahcDriver::new(&single, c1, &NativeBackend::new())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(res.labels, vec![0]);
    assert_eq!(res.k, 1);
}
