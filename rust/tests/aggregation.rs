//! Stage-0 aggregation conformance: determinism across threads and
//! backends, the ε = 0 bitwise pin, batched-probe parity against the
//! per-row reference path, the quantile-ε oracle, the two-level-tree
//! degenerate pins, degenerate corpora, and the full-corpus label
//! guarantee.
//!
//! The fixture of choice is a *duplicated* corpus — every segment
//! appears twice — because it makes the leader pass provable: exact
//! duplicates sit at DTW distance 0, every distinct pair sits at ≥ the
//! corpus's smallest nonzero distance, so with ε strictly between the
//! two each duplicate must join its original's group and nothing else
//! merges.

mod common;

use mahc::aggregate::{aggregate, derive_epsilon, quantile_of_sorted};
use mahc::config::{AggregateConfig, AlgoConfig, Convergence, DatasetSpec, StreamConfig};
use mahc::corpus::{generate, Segment, SegmentSet};
use mahc::distance::{build_condensed, BlockedBackend, PairwiseBackend, NativeBackend, PairCache};
use mahc::mahc::{MahcDriver, StreamingDriver};

/// All pair distances of a corpus, sorted ascending — the exact
/// population the quantile estimator samples from.
fn sorted_pair_distances(set: &SegmentSet) -> Vec<f32> {
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let cond = build_condensed(&refs, &NativeBackend::new(), 4).unwrap();
    let mut dists: Vec<f32> = cond.as_slice().to_vec();
    dists.sort_unstable_by(f32::total_cmp);
    dists
}

/// A corpus where segment `n + i` is an exact copy of segment `i`.
fn duplicated_corpus(n: usize, classes: usize, seed: u64) -> SegmentSet {
    let base = generate(&DatasetSpec::tiny(n, classes, seed));
    let mut segments = base.segments.clone();
    for i in 0..n {
        let mut dup = base.segments[i].clone();
        dup.id = n + i;
        segments.push(dup);
    }
    let set = SegmentSet {
        name: format!("{}_doubled", base.name),
        dim: base.dim,
        segments,
        num_classes: base.num_classes,
    };
    set.validate().expect("duplicated corpus is well-formed");
    set
}

/// Half the smallest nonzero pair distance: duplicates (distance 0)
/// merge, distinct segments (distance ≥ 2ε) never do.
fn below_min_nonzero_distance(set: &SegmentSet) -> f32 {
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let cond = build_condensed(&refs, &NativeBackend::new(), 4).unwrap();
    let min_nonzero = cond
        .as_slice()
        .iter()
        .copied()
        .filter(|&d| d > 0.0)
        .fold(f32::INFINITY, f32::min);
    assert!(min_nonzero.is_finite() && min_nonzero > 0.0);
    min_nonzero * 0.5
}

fn cfg(eps: f32) -> AlgoConfig {
    AlgoConfig {
        p0: 3,
        beta: Some(40),
        convergence: Convergence::FixedIters(3),
        aggregate: AggregateConfig::new(eps),
        ..Default::default()
    }
}

#[test]
fn duplicates_collapse_onto_their_originals() {
    let n = 60;
    let set = duplicated_corpus(n, 5, 201);
    let eps = below_min_nonzero_distance(&set);
    let agg = aggregate(
        &set,
        &AggregateConfig::new(eps),
        &NativeBackend::new(),
        4,
        None,
    )
    .unwrap();
    // Every duplicate shares its original's representative; only
    // zero-distance pairs merged, so at most the originals remain.
    assert!(agg.reps() <= n, "{} reps > {n} originals", agg.reps());
    assert!(agg.compression_ratio() <= 0.5);
    for i in 0..n {
        assert_eq!(
            agg.rep_of[i],
            agg.rep_of[n + i],
            "duplicate {i} strayed from its original's group"
        );
    }

    // End to end: the aggregated run labels all 2n segments, gives
    // duplicate pairs identical labels, and stays close to the
    // unaggregated run's quality.
    let plain = MahcDriver::new(&set, cfg(0.0), &NativeBackend::new())
        .unwrap()
        .run()
        .unwrap();
    let res = MahcDriver::new(&set, cfg(eps), &NativeBackend::new())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(res.labels.len(), 2 * n);
    assert!(res.labels.iter().all(|&l| l < res.k));
    for i in 0..n {
        assert_eq!(
            res.labels[i],
            res.labels[n + i],
            "duplicate {i} labelled apart from its original"
        );
    }
    assert!(
        res.f_measure > plain.f_measure - 0.1,
        "aggregated F {:.3} fell too far under plain {:.3}",
        res.f_measure,
        plain.f_measure
    );
    let r0 = &res.history.records[0];
    assert_eq!(r0.representatives, agg.reps());
    assert!(r0.compression_ratio <= 0.5);
    assert_eq!(r0.assignment_pairs, agg.probe_pairs);
}

#[test]
fn aggregation_is_invariant_to_threads_and_backend() {
    let set = duplicated_corpus(40, 4, 202);
    let eps = below_min_nonzero_distance(&set);
    let native = NativeBackend::new();
    let blocked = BlockedBackend::new();
    let backends: [(&str, &dyn PairwiseBackend); 2] = [("native", &native), ("blocked", &blocked)];

    let reference = aggregate(&set, &AggregateConfig::new(eps), &native, 1, None).unwrap();
    let mut runs = Vec::new();
    for (bname, backend) in backends {
        let a = aggregate(&set, &AggregateConfig::new(eps), backend, 4, None).unwrap();
        assert_eq!(a.rep_ids, reference.rep_ids, "{bname}: rep set diverged");
        assert_eq!(a.members, reference.members, "{bname}: memberships diverged");
        assert_eq!(a.rep_of, reference.rep_of, "{bname}");
        assert_eq!(a.probe_pairs, reference.probe_pairs, "{bname}");
        // Built-in sweep plus this CI matrix cell's MAHC_TEST_THREADS.
        for threads in common::thread_matrix(&[1, 8]) {
            let mut c = cfg(eps);
            c.threads = threads;
            let res = MahcDriver::new(&set, c, backend).unwrap().run().unwrap();
            runs.push((format!("{bname}/t{threads}"), res));
        }
    }
    let (ref_name, ref_run) = &runs[0];
    for (name, run) in &runs[1..] {
        assert_eq!(
            run.labels, ref_run.labels,
            "{name} labels diverged from {ref_name}"
        );
        assert_eq!(run.k, ref_run.k, "{name}");
        assert_eq!(
            run.f_measure.to_bits(),
            ref_run.f_measure.to_bits(),
            "{name}"
        );
    }
}

#[test]
fn epsilon_zero_batch_run_is_bitwise_the_unaggregated_run() {
    let set = generate(&DatasetSpec::tiny(90, 6, 203));
    let backend = NativeBackend::new();
    let mut plain_cfg = cfg(0.0);
    plain_cfg.aggregate = AggregateConfig::default();
    let mut zero_cfg = cfg(0.0);
    zero_cfg.aggregate.cap = Some(7); // cap without ε is inert
    zero_cfg.aggregate.batch_rows = 5; // probe-engine knobs too
    zero_cfg.aggregate.tree_factor = 3.0;
    zero_cfg.aggregate.tree_probe = 1;
    let plain = MahcDriver::new(&set, plain_cfg, &backend)
        .unwrap()
        .run()
        .unwrap();
    let zero = MahcDriver::new(&set, zero_cfg, &backend)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(plain.labels, zero.labels);
    assert_eq!(plain.k, zero.k);
    assert_eq!(plain.f_measure.to_bits(), zero.f_measure.to_bits());
    assert_eq!(plain.history.algo, zero.history.algo);
    assert_eq!(plain.history.records.len(), zero.history.records.len());
    for (a, b) in plain.history.records.iter().zip(&zero.history.records) {
        assert_eq!(a.subsets, b.subsets);
        assert_eq!(a.max_occupancy, b.max_occupancy);
        assert_eq!(a.min_occupancy, b.min_occupancy);
        assert_eq!(a.max_occupancy_pre_split, b.max_occupancy_pre_split);
        assert_eq!(a.splits, b.splits);
        assert_eq!(a.total_clusters, b.total_clusters);
        assert_eq!(a.f_measure.to_bits(), b.f_measure.to_bits());
        assert_eq!(a.peak_matrix_bytes, b.peak_matrix_bytes);
        assert_eq!(a.cache, b.cache, "pair counters must match");
        assert_eq!(b.representatives, 0);
        assert_eq!(b.compression_ratio, 1.0);
        assert_eq!(b.assignment_pairs, 0);
        assert_eq!(b.probe_rounds, 0);
        assert_eq!(b.super_leaders, 0);
        assert_eq!(b.aggregate_epsilon, 0.0);
    }
}

#[test]
fn aggregated_stream_labels_everyone_and_matches_plain_at_epsilon_zero() {
    let set = duplicated_corpus(45, 4, 204);
    let eps = below_min_nonzero_distance(&set);
    let backend = NativeBackend::new();

    // ε = 0, bitwise against the never-aggregated stream.
    let plain = StreamingDriver::new(
        &set,
        StreamConfig::new(
            AlgoConfig {
                aggregate: AggregateConfig::default(),
                ..cfg(0.0)
            },
            30,
        ),
        &backend,
    )
    .unwrap()
    .run()
    .unwrap();
    let zero = StreamingDriver::new(&set, StreamConfig::new(cfg(0.0), 30), &backend)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(plain.labels, zero.labels);
    assert_eq!(plain.k, zero.k);
    assert_eq!(plain.f_measure.to_bits(), zero.f_measure.to_bits());

    // ε > 0: the stream runs over representatives (duplicates halve
    // it), still labels all 90 segments, duplicates together.
    let agg = StreamingDriver::new(&set, StreamConfig::new(cfg(eps), 30), &backend)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(agg.labels.len(), 90);
    assert!(agg.labels.iter().all(|&l| l < agg.k));
    for i in 0..45 {
        assert_eq!(agg.labels[i], agg.labels[45 + i], "duplicate {i}");
    }
    let r0 = &agg.history.records[0];
    assert!(r0.representatives <= 45);
    assert!(r0.compression_ratio <= 0.5);
    assert!(r0.assignment_pairs > 0);
    // Fewer representatives than segments means fewer shards than the
    // plain stream of the same shard size.
    assert!(agg.shards <= plain.shards);
}

#[test]
fn cache_is_shared_between_leader_pass_and_stage1() {
    let set = duplicated_corpus(50, 4, 205);
    let eps = below_min_nonzero_distance(&set);
    let backend = NativeBackend::new();
    let plain = MahcDriver::new(&set, cfg(eps), &backend)
        .unwrap()
        .run()
        .unwrap();
    let mut cached_cfg = cfg(eps);
    cached_cfg.cache_bytes = 8 << 20;
    let cached = MahcDriver::new(&set, cached_cfg, &backend)
        .unwrap()
        .run()
        .unwrap();
    // The cache must not change a bit of the aggregated pipeline...
    assert_eq!(plain.labels, cached.labels);
    assert_eq!(plain.k, cached.k);
    assert_eq!(plain.f_measure.to_bits(), cached.f_measure.to_bits());
    // ...and stage 1 must reuse leader-pass probes: every (rep, rep)
    // pair was probed when the newer rep was admitted, so iteration 1's
    // condensed builds see warm pairs immediately.
    assert!(
        cached.history.records[0].cache.hits > 0,
        "stage 1 found no warm leader-pass pairs: {:?}",
        cached.history.records[0].cache
    );
}

#[test]
fn degenerate_corpora_are_pinned() {
    // All-identical segments: one group without a cap, ⌈n/cap⌉ groups
    // with one, and the driver runs cleanly on the collapsed corpus.
    let base = generate(&DatasetSpec::tiny(12, 2, 206));
    let proto = base.segments[0].clone();
    let n = 9;
    let identical = SegmentSet {
        name: "identical".into(),
        dim: base.dim,
        segments: (0..n)
            .map(|id| Segment {
                id,
                class_id: 0,
                len: proto.len,
                dim: proto.dim,
                feats: proto.feats.clone(),
            })
            .collect(),
        num_classes: 1,
    };
    identical.validate().unwrap();

    let free = aggregate(
        &identical,
        &AggregateConfig::new(0.5),
        &NativeBackend::new(),
        1,
        None,
    )
    .unwrap();
    assert_eq!(free.reps(), 1);
    assert_eq!(free.members[0].len(), n);

    let capped = aggregate(
        &identical,
        &AggregateConfig::new(0.5).with_cap(4),
        &NativeBackend::new(),
        1,
        None,
    )
    .unwrap();
    assert_eq!(capped.reps(), 3, "⌈9/4⌉ saturated groups");
    assert_eq!(
        capped.members.iter().map(Vec::len).collect::<Vec<_>>(),
        vec![4, 4, 1]
    );

    let mut c = cfg(0.5);
    c.p0 = 1;
    c.beta = None;
    let res = MahcDriver::new(&identical, c, &NativeBackend::new())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(res.labels, vec![0; n], "identical corpus is one cluster");
    assert_eq!(res.k, 1);
    assert_eq!(res.f_measure, 1.0, "single class, single cluster");

    // Single-segment corpus: aggregation is the identity and the run
    // still works.
    let single = SegmentSet {
        name: "single".into(),
        dim: proto.dim,
        segments: vec![Segment {
            id: 0,
            class_id: 0,
            len: proto.len,
            dim: proto.dim,
            feats: proto.feats.clone(),
        }],
        num_classes: 1,
    };
    let agg = aggregate(
        &single,
        &AggregateConfig::new(1.0),
        &NativeBackend::new(),
        1,
        None,
    )
    .unwrap();
    assert!(agg.is_identity());
    let mut c1 = cfg(1.0);
    c1.p0 = 1;
    c1.beta = None;
    let res = MahcDriver::new(&single, c1, &NativeBackend::new())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(res.labels, vec![0]);
    assert_eq!(res.k, 1);
}

#[test]
fn batched_probing_is_bitwise_the_per_row_reference() {
    // The rectangle-batched probe engine reorders *when* distances are
    // computed, never *which decision* is taken: representatives,
    // memberships and end-to-end labels must be bitwise identical to
    // the serial per-row path (batch_rows = 1) across the full parity
    // matrix — threads x backends x batch sizes, with and without a
    // mid-round-saturating cap.
    let set = generate(&DatasetSpec::tiny(70, 6, 210));
    let eps = quantile_of_sorted(&sorted_pair_distances(&set), 0.25);
    let native = NativeBackend::new();
    let blocked = BlockedBackend::new();
    let backends: [(&str, &dyn PairwiseBackend); 2] = [("scalar", &native), ("blocked", &blocked)];

    for cap in [None, Some(4)] {
        let mut per_row = AggregateConfig::new(eps).with_batch_rows(1);
        per_row.cap = cap;
        let reference = aggregate(&set, &per_row, &native, 1, None).unwrap();
        assert_eq!(
            reference.probe_rounds,
            set.len(),
            "per-row reference runs one round per segment"
        );
        for (bname, backend) in backends {
            for threads in common::thread_matrix(&[1, 8]) {
                for batch in [2usize, 16, 64] {
                    let mut cfg = per_row;
                    cfg.batch_rows = batch;
                    let got = aggregate(&set, &cfg, backend, threads, None).unwrap();
                    let ctx = format!("{bname}/t{threads}/batch{batch}/cap{cap:?}");
                    assert_eq!(got.rep_ids, reference.rep_ids, "{ctx}: rep set");
                    assert_eq!(got.members, reference.members, "{ctx}: memberships");
                    assert_eq!(got.rep_of, reference.rep_of, "{ctx}: rep_of");
                    assert_eq!(got.probe_rounds, set.len().div_ceil(batch), "{ctx}");
                    if cap.is_none() {
                        // Without a cap every round past the first has
                        // open columns, so a rectangle must have gone out.
                        assert!(got.rect_cols > 0, "{ctx}: rectangles must dispatch");
                        assert_eq!(
                            got.probe_pairs, reference.probe_pairs,
                            "{ctx}: uncapped probe counts are dispatch-shape free"
                        );
                    }
                }
            }
        }
    }

    // End to end: the full pipeline's labels ride on the grouping, so
    // they inherit the parity.
    let mk = |batch: usize| {
        let mut c = cfg(eps);
        c.aggregate.batch_rows = batch;
        c
    };
    let ref_run = MahcDriver::new(&set, mk(1), &native)
        .unwrap()
        .run()
        .unwrap();
    for (bname, backend) in backends {
        for threads in common::thread_matrix(&[1, 8]) {
            let mut c = mk(64);
            c.threads = threads;
            let run = MahcDriver::new(&set, c, backend).unwrap().run().unwrap();
            assert_eq!(run.labels, ref_run.labels, "{bname}/t{threads}: labels");
            assert_eq!(run.k, ref_run.k, "{bname}/t{threads}");
            assert_eq!(
                run.f_measure.to_bits(),
                ref_run.f_measure.to_bits(),
                "{bname}/t{threads}"
            );
        }
    }
}

#[test]
fn quantile_epsilon_oracle() {
    let set = generate(&DatasetSpec::tiny(40, 4, 211));
    let native = NativeBackend::new();
    let exact = sorted_pair_distances(&set);

    // A sample covering the corpus IS the exact quantile, bit for bit,
    // whatever the seed.
    for q in [0.1, 0.5, 0.75] {
        let est = derive_epsilon(&set, q, set.len(), 5, &native, 4, None).unwrap();
        assert_eq!(est.sample_pairs, exact.len());
        assert_eq!(est.sample_segments, set.len());
        assert_eq!(
            est.epsilon.to_bits(),
            quantile_of_sorted(&exact, q).to_bits(),
            "full-sample estimate must be exact at q = {q}"
        );
    }

    // A strict sample is seed-deterministic and thread-invariant, and
    // lands within the documented tolerance: between the exact
    // quantiles at q - 0.25 and q + 0.25.
    let q = 0.5;
    let est_a = derive_epsilon(&set, q, 20, 9, &native, 4, None).unwrap();
    let est_b = derive_epsilon(&set, q, 20, 9, &native, 1, None).unwrap();
    let (a, pa) = (est_a.epsilon, est_a.sample_pairs);
    let (b, pb) = (est_b.epsilon, est_b.sample_pairs);
    assert_eq!(a.to_bits(), b.to_bits(), "same seed, same estimate");
    assert_eq!(pa, pb);
    assert_eq!(pa, 20 * 19 / 2, "sample of 20 segments has C(20,2) pairs");
    assert_eq!(est_a.sample_segments, 20);
    let lo = quantile_of_sorted(&exact, q - 0.25);
    let hi = quantile_of_sorted(&exact, q + 0.25);
    assert!(
        lo <= a && a <= hi,
        "sampled estimate {a} outside the tolerance window [{lo}, {hi}]"
    );

    // q outside (0, 1) is rejected by config validation and by the
    // pass itself.
    for q in [0.0, 1.0, -1.0, 2.0, f64::NAN] {
        let mut c = AlgoConfig::default();
        c.aggregate = AggregateConfig::default().with_quantile(q);
        assert!(c.validate().is_err(), "config must reject q = {q}");
        assert!(
            aggregate(&set, &c.aggregate, &native, 1, None).is_err(),
            "aggregate must reject q = {q}"
        );
    }

    // End to end: a quantile-configured run is bitwise the absolute-ε
    // run at the derived radius, and stamps that radius in telemetry.
    let seed = AggregateConfig::default().quantile_seed;
    let eps25 = derive_epsilon(&set, 0.25, 256, seed, &native, 4, None)
        .unwrap()
        .epsilon;
    assert!(eps25 > 0.0, "p25 of distinct random segments is nonzero");
    let mut qcfg = cfg(0.0);
    qcfg.aggregate = AggregateConfig::default().with_quantile(0.25);
    let arun = MahcDriver::new(&set, cfg(eps25), &native)
        .unwrap()
        .run()
        .unwrap();
    let qrun = MahcDriver::new(&set, qcfg, &native).unwrap().run().unwrap();
    assert_eq!(qrun.labels, arun.labels);
    assert_eq!(qrun.k, arun.k);
    assert_eq!(qrun.f_measure.to_bits(), arun.f_measure.to_bits());
    assert_eq!(qrun.history.aggregate_epsilon(), eps25 as f64);
    assert_eq!(arun.history.aggregate_epsilon(), eps25 as f64);
    // The estimate's cost is visible: C(40,2) sampled pairs on the
    // quantile run, none on the absolute-ε run.
    assert_eq!(qrun.history.sample_pairs(), 40 * 39 / 2);
    assert_eq!(arun.history.sample_pairs(), 0);
}

#[test]
fn tree_degenerate_pins_match_the_flat_pass() {
    let native = NativeBackend::new();

    // Pin 1: one covering super-group.  A coarse radius beyond every
    // pair distance puts all leaders under super 0, so each segment
    // descends into the full open-leader set — exactly the flat pass.
    let set = generate(&DatasetSpec::tiny(50, 5, 212));
    let dists = sorted_pair_distances(&set);
    let eps = quantile_of_sorted(&dists, 0.25);
    let d_max = *dists.last().unwrap();
    let flat = aggregate(&set, &AggregateConfig::new(eps), &native, 4, None).unwrap();
    for fan in [1usize, 2, 4] {
        let covering = AggregateConfig::new(eps).with_tree(d_max * 2.0 / eps, fan);
        let tree = aggregate(&set, &covering, &native, 4, None).unwrap();
        assert_eq!(tree.rep_ids, flat.rep_ids, "fan = {fan}: rep set");
        assert_eq!(tree.members, flat.members, "fan = {fan}: memberships");
        assert_eq!(tree.rep_of, flat.rep_of, "fan = {fan}");
        assert_eq!(tree.super_leaders, 1, "one covering super-group");
    }

    // Pin 2: fan-out 1 over singleton super-groups.  A coarse radius
    // below the smallest leader-to-leader distance makes every leader
    // its own super-leader; on the duplicated corpus the nearest super
    // is the duplicate's original at distance 0, so descending into a
    // single group cannot prune the join target away.
    let dup = duplicated_corpus(30, 4, 213);
    let eps_dup = below_min_nonzero_distance(&dup);
    let flat_dup = aggregate(&dup, &AggregateConfig::new(eps_dup), &native, 4, None).unwrap();
    let pinned = AggregateConfig::new(eps_dup).with_tree(1e-3, 1);
    let tree_dup = aggregate(&dup, &pinned, &native, 4, None).unwrap();
    assert_eq!(tree_dup.rep_ids, flat_dup.rep_ids);
    assert_eq!(tree_dup.members, flat_dup.members);
    assert_eq!(tree_dup.rep_of, flat_dup.rep_of);
    assert_eq!(
        tree_dup.super_leaders,
        tree_dup.reps(),
        "every leader its own super-leader"
    );

    // Pin 3: cap-saturated super-groups.  On an all-identical corpus
    // every group under the single super fills to the cap and the
    // overflow founds fresh leaders — same ⌈n/cap⌉ groups as flat.
    let base = generate(&DatasetSpec::tiny(12, 2, 214));
    let proto = base.segments[0].clone();
    let n = 9;
    let identical = SegmentSet {
        name: "identical".into(),
        dim: base.dim,
        segments: (0..n)
            .map(|id| Segment {
                id,
                class_id: 0,
                len: proto.len,
                dim: proto.dim,
                feats: proto.feats.clone(),
            })
            .collect(),
        num_classes: 1,
    };
    identical.validate().unwrap();
    let flat_cap = AggregateConfig::new(0.5).with_cap(4);
    let flat_id = aggregate(&identical, &flat_cap, &native, 1, None).unwrap();
    assert_eq!(flat_id.reps(), 3, "⌈9/4⌉ saturated groups");
    for factor in [0.5f32, 1e6] {
        let tree_cap = flat_cap.with_tree(factor, 1);
        let tree_id = aggregate(&identical, &tree_cap, &native, 1, None).unwrap();
        assert_eq!(tree_id.rep_ids, flat_id.rep_ids, "factor = {factor}");
        assert_eq!(tree_id.members, flat_id.members, "factor = {factor}");
        assert_eq!(tree_id.super_leaders, 1, "all-zero distances share one super");
    }
}

#[test]
fn batched_and_tree_probes_move_the_shared_cache_honestly() {
    // Every issued probe — rectangle cell, fresh-leader row, tree
    // descent — passes through the shared PairCache exactly once, and
    // a cold pass probes only distinct pairs: hits + misses must equal
    // the issued probe count.
    let set = generate(&DatasetSpec::tiny(50, 5, 215));
    let eps = quantile_of_sorted(&sorted_pair_distances(&set), 0.25);
    let native = NativeBackend::new();
    let flat16 = AggregateConfig::new(eps).with_batch_rows(16);
    let tree16 = flat16.with_tree(4.0, 2);
    for probe_cfg in [flat16, tree16] {
        let cache = PairCache::with_capacity_bytes(8 << 20);
        let agg = aggregate(&set, &probe_cfg, &native, 4, Some(&cache)).unwrap();
        let s = cache.stats();
        assert_eq!(
            (s.hits + s.misses) as usize,
            agg.probe_pairs,
            "issued probes must all pass through the cache"
        );
        assert_eq!(s.hits, 0, "a cold pass probes only distinct pairs");
    }

    // Driver level: the leader pass runs before the first episode
    // snapshot, so its counter movement — batched rectangles included —
    // is folded into record 0 the way single-row probes always were.
    let mut dcfg = cfg(eps);
    dcfg.cache_bytes = 8 << 20;
    let res = MahcDriver::new(&set, dcfg, &native).unwrap().run().unwrap();
    let r0 = &res.history.records[0];
    assert!(r0.assignment_pairs > 0, "aggregation must have probed");
    assert!(
        (r0.cache.hits + r0.cache.misses) as usize >= r0.assignment_pairs,
        "leader-pass probes folded into the first record: {:?}",
        r0.cache
    );
    assert!(r0.probe_rounds > 0, "probe telemetry stamped on record 0");
    assert_eq!(r0.aggregate_epsilon, eps as f64);
}
