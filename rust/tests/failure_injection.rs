//! Failure injection: errors from the distance backend must propagate
//! cleanly through the builder, stage-1 workers and the driver — no
//! panics, no poisoned pools, no partial results presented as success.

use std::sync::atomic::{AtomicUsize, Ordering};

use mahc::config::{AlgoConfig, Convergence, DatasetSpec};
use mahc::corpus::{generate, Segment};
use mahc::distance::{build_condensed, build_cross, PairwiseBackend, NativeBackend};
use mahc::mahc::MahcDriver;

/// Backend that fails after a configurable number of calls.
struct FlakyBackend {
    inner: NativeBackend,
    calls: AtomicUsize,
    fail_after: usize,
}

impl FlakyBackend {
    fn new(fail_after: usize) -> Self {
        FlakyBackend {
            inner: NativeBackend::new(),
            calls: AtomicUsize::new(0),
            fail_after,
        }
    }
}

impl PairwiseBackend for FlakyBackend {
    fn pairwise(&self, xs: &[&Segment], ys: &[&Segment]) -> anyhow::Result<Vec<f32>> {
        let n = self.calls.fetch_add(1, Ordering::SeqCst);
        if n >= self.fail_after {
            anyhow::bail!("injected backend failure (call {n})");
        }
        self.inner.pairwise(xs, ys)
    }

    fn name(&self) -> &'static str {
        "flaky"
    }
}

/// Backend that returns the wrong number of distances.
struct WrongShapeBackend;

impl PairwiseBackend for WrongShapeBackend {
    fn pairwise(&self, _xs: &[&Segment], _ys: &[&Segment]) -> anyhow::Result<Vec<f32>> {
        Ok(vec![0.0; 1]) // always wrong for multi-pair requests
    }

    fn name(&self) -> &'static str {
        "wrong-shape"
    }
}

fn tiny_set() -> mahc::corpus::SegmentSet {
    generate(&DatasetSpec::tiny(40, 3, 9))
}

#[test]
fn builder_propagates_backend_error() {
    let set = tiny_set();
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let backend = FlakyBackend::new(0);
    let err = build_condensed(&refs, &backend, 4).unwrap_err();
    assert!(err.to_string().contains("injected"), "{err}");
}

#[test]
fn builder_fails_even_when_error_is_late() {
    let set = tiny_set();
    let refs: Vec<&Segment> = set.segments.iter().collect();
    // Fail on the 20th call: earlier rows already succeeded.
    let backend = FlakyBackend::new(20);
    assert!(build_condensed(&refs, &backend, 2).is_err());
}

#[test]
fn cross_builder_propagates_error() {
    let set = tiny_set();
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let backend = FlakyBackend::new(0);
    assert!(build_cross(&refs[..5], &refs[5..], &backend, 2).is_err());
}

#[test]
fn driver_surfaces_stage1_failure() {
    let set = tiny_set();
    let backend = FlakyBackend::new(1); // first subset OK, then die
    let cfg = AlgoConfig {
        p0: 4,
        convergence: Convergence::FixedIters(3),
        ..Default::default()
    };
    let err = MahcDriver::new(&set, cfg, &backend)
        .unwrap()
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("injected"), "{err}");
}

#[test]
fn driver_survives_and_reports_after_success_then_failure() {
    // Enough successful calls for iteration 0 (stage1 + medoids), then
    // failure mid-run: the error must surface, not a bogus result.
    let set = tiny_set();
    let backend = FlakyBackend::new(6);
    let cfg = AlgoConfig {
        p0: 2,
        convergence: Convergence::FixedIters(4),
        ..Default::default()
    };
    let res = MahcDriver::new(&set, cfg, &backend).unwrap().run();
    assert!(res.is_err());
}

#[test]
fn mismatched_backend_output_is_not_silently_accepted() {
    // The condensed builder indexes into the returned buffer; a short
    // buffer must panic (slice bounds) or error, never silently corrupt.
    let set = tiny_set();
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let result = std::panic::catch_unwind(|| {
        build_condensed(&refs, &WrongShapeBackend, 1)
    });
    match result {
        Ok(Ok(_)) => panic!("wrong-shaped output accepted"),
        Ok(Err(_)) | Err(_) => {} // error or panic both acceptable rejections
    }
}

#[test]
fn empty_and_single_segment_inputs() {
    let backend = NativeBackend::new();
    let empty: Vec<&Segment> = Vec::new();
    let cond = build_condensed(&empty, &backend, 2).unwrap();
    assert_eq!(cond.n(), 0);
    let set = tiny_set();
    let one = vec![&set.segments[0]];
    let cond = build_condensed(&one, &backend, 2).unwrap();
    assert_eq!(cond.n(), 1);
    assert_eq!(cond.len(), 0);
}
