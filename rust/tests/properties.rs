//! Property-based tests over the coordinator's invariants.
//!
//! No proptest in the vendor set, so this is a seeded-sweep harness:
//! each property is checked over many randomly generated configurations
//! (seeds printed on failure for reproduction).  Shrinking is traded
//! for breadth — cases are small, so a failing seed is directly
//! debuggable.

use mahc::config::{AlgoConfig, Convergence, DatasetSpec, FinalK};
use mahc::corpus::generate;
use mahc::distance::{build_condensed, Condensed, NativeBackend};
use mahc::mahc::{even_partition, initial_partition, split_oversized, MahcDriver};
use mahc::util::rng::Rng;

/// Run `f` over `n` seeded cases, reporting the failing seed.
fn for_seeds(n: u64, f: impl Fn(u64)) {
    for seed in 0..n {
        f(seed);
    }
}

#[test]
fn prop_partition_is_exact_cover() {
    for_seeds(25, |seed| {
        let mut rng = Rng::seed_from(seed);
        let n = 1 + rng.range(0, 400);
        let p = 1 + rng.range(0, 12);
        let parts = initial_partition(n, p, &mut rng);
        let mut all: Vec<usize> = parts.concat();
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>(), "seed {seed} n={n} p={p}");
        assert!(parts.iter().all(|s| !s.is_empty()), "seed {seed}");
    });
}

#[test]
fn prop_split_never_exceeds_beta_and_preserves_members() {
    for_seeds(25, |seed| {
        let mut rng = Rng::seed_from(1000 + seed);
        let n_subsets = 1 + rng.range(0, 6);
        let beta = 4 + rng.range(0, 60);
        let mut subsets: Vec<Vec<usize>> = Vec::new();
        let mut next_id = 0;
        for _ in 0..n_subsets {
            let size = 1 + rng.range(0, 300);
            subsets.push((next_id..next_id + size).collect());
            next_id += size;
        }
        let before: usize = subsets.iter().map(|s| s.len()).sum();
        split_oversized(&mut subsets, beta, &mut rng, seed % 2 == 0);
        assert!(
            subsets.iter().all(|s| s.len() <= beta),
            "seed {seed}: β={beta} violated"
        );
        let mut all: Vec<usize> = subsets.concat();
        all.sort_unstable();
        assert_eq!(all.len(), before, "seed {seed}: members lost");
        all.dedup();
        assert_eq!(all.len(), before, "seed {seed}: members duplicated");
        // Balance: pieces from one split differ by ≤ 1... the global
        // guarantee is weaker, but no subset may be empty.
        assert!(subsets.iter().all(|s| !s.is_empty()), "seed {seed}");
    });
}

#[test]
fn prop_even_partition_balanced() {
    for_seeds(40, |seed| {
        let mut rng = Rng::seed_from(2000 + seed);
        let n = 1 + rng.range(0, 500);
        let p = 1 + rng.range(0, 20);
        let ids: Vec<usize> = (0..n).collect();
        let parts = even_partition(&ids, p);
        let max = parts.iter().map(|s| s.len()).max().unwrap();
        let min = parts.iter().map(|s| s.len()).min().unwrap();
        assert!(max - min <= 1, "seed {seed}: {max}-{min}");
    });
}

#[test]
fn prop_driver_output_is_valid_partition() {
    // Whole-driver invariant sweep over random small configs.
    for_seeds(6, |seed| {
        let mut rng = Rng::seed_from(3000 + seed);
        let n = 40 + rng.range(0, 80);
        let classes = 3 + rng.range(0, 5);
        let set = generate(&DatasetSpec::tiny(n, classes, seed));
        let p0 = 1 + rng.range(0, 5);
        let beta = if rng.f64() < 0.5 {
            Some(10 + rng.range(0, n))
        } else {
            None
        };
        let cfg = AlgoConfig {
            p0,
            beta,
            convergence: Convergence::FixedIters(2 + rng.range(0, 3)),
            final_k: if rng.f64() < 0.3 {
                FinalK::Fixed(1 + rng.range(0, classes * 2))
            } else {
                FinalK::StageOneTotal
            },
            seed,
            ..Default::default()
        };
        let backend = NativeBackend::new();
        let res = MahcDriver::new(&set, cfg.clone(), &backend)
            .unwrap()
            .run()
            .unwrap();
        // Valid dense labelling.
        assert_eq!(res.labels.len(), n, "seed {seed}");
        assert!(res.k >= 1, "seed {seed}");
        assert!(
            res.labels.iter().all(|&l| l < res.k),
            "seed {seed}: label out of range"
        );
        let used: std::collections::HashSet<_> = res.labels.iter().collect();
        assert_eq!(used.len(), res.k, "seed {seed}: empty final cluster");
        // β invariant when management is on.
        if let Some(b) = cfg.beta {
            for r in &res.history.records {
                assert!(r.max_occupancy <= b, "seed {seed}: β breached");
            }
        }
        // Occupancy sanity: Σ subset sizes is n every iteration — the
        // max/min bounds imply max*P ≥ n ≥ min*P.
        for r in &res.history.records {
            assert!(r.max_occupancy * r.subsets >= n, "seed {seed}");
            assert!(r.min_occupancy * r.subsets <= n, "seed {seed}");
            assert!(r.min_occupancy >= 1, "seed {seed}: empty subset");
        }
    });
}

#[test]
fn prop_ward_heights_nonnegative_and_sorted() {
    for_seeds(15, |seed| {
        let mut rng = Rng::seed_from(4000 + seed);
        let n = 2 + rng.range(0, 60);
        let mut cond = Condensed::zeros(n);
        for i in 0..n {
            for j in 0..i {
                cond.set(i, j, rng.f32() * 10.0);
            }
        }
        let dendro = mahc::ahc::ward_linkage(&cond);
        let h = dendro.merge_heights();
        assert_eq!(h.len(), n - 1, "seed {seed}");
        assert!(h.iter().all(|&x| x >= 0.0), "seed {seed}");
        for w in h.windows(2) {
            assert!(w[0] <= w[1], "seed {seed}: heights unsorted");
        }
        // Every cut k yields exactly k clusters.
        for k in 1..=n.min(6) {
            let labels = dendro.cut(k);
            let used: std::collections::HashSet<_> = labels.iter().collect();
            assert_eq!(used.len(), k, "seed {seed} k={k}");
        }
    });
}

#[test]
fn prop_condensed_symmetric_consistency() {
    for_seeds(10, |seed| {
        let set = generate(&DatasetSpec::tiny(24, 3, 5000 + seed));
        let refs: Vec<&mahc::corpus::Segment> = set.segments.iter().collect();
        let cond = build_condensed(&refs, &NativeBackend::new(), 3).unwrap();
        for i in 0..refs.len() {
            for j in 0..refs.len() {
                assert_eq!(cond.get(i, j), cond.get(j, i), "seed {seed}");
            }
            assert_eq!(cond.get(i, i), 0.0);
        }
        assert!(cond.as_slice().iter().all(|&d| d >= 0.0), "seed {seed}");
    });
}

#[test]
fn prop_f_measure_bounds_and_perfect_case() {
    for_seeds(30, |seed| {
        let mut rng = Rng::seed_from(6000 + seed);
        let n = 1 + rng.range(0, 200);
        let kc = 1 + rng.range(0, 10);
        let truth: Vec<usize> = (0..n).map(|_| rng.range(0, kc)).collect();
        let pred: Vec<usize> = (0..n).map(|_| rng.range(0, kc)).collect();
        let f = mahc::metrics::f_measure(&pred, &truth);
        assert!((0.0..=1.0).contains(&f), "seed {seed}: F={f}");
        let f_perfect = mahc::metrics::f_measure(&truth, &truth);
        assert!((f_perfect - 1.0).abs() < 1e-12, "seed {seed}");
        assert!(f <= f_perfect + 1e-12, "seed {seed}");
    });
}
