//! End-to-end pipeline integration: corpus → distance backend → MAHC(±M)
//! → metrics, on both backends, checking the paper's headline claims at
//! test scale.

use mahc::baselines::full_ahc;
use mahc::config::{AlgoConfig, Convergence, DatasetSpec};
use mahc::corpus::generate;
use mahc::distance::NativeBackend;
use mahc::mahc::MahcDriver;
use mahc::metrics;
use mahc::runtime::{Runtime, XlaDtwBackend};
use std::path::Path;

fn cfg(p0: usize, beta: Option<usize>, iters: usize) -> AlgoConfig {
    AlgoConfig {
        p0,
        beta,
        convergence: Convergence::FixedIters(iters),
        ..Default::default()
    }
}

#[test]
fn mahc_m_matches_mahc_f_measure_at_test_scale() {
    // Paper claim 2: size management costs no F-measure.
    let set = generate(&DatasetSpec::tiny(180, 9, 101));
    let backend = NativeBackend::new();
    let plain = MahcDriver::new(&set, cfg(4, None, 4), &backend)
        .unwrap()
        .run()
        .unwrap();
    let managed = MahcDriver::new(&set, cfg(4, Some(60), 4), &backend)
        .unwrap()
        .run()
        .unwrap();
    assert!(
        managed.f_measure > plain.f_measure - 0.1,
        "managed {:.3} vs plain {:.3}",
        managed.f_measure,
        plain.f_measure
    );
    // Claim 1: β bound held everywhere.
    for r in &managed.history.records {
        assert!(r.max_occupancy <= 60);
    }
}

#[test]
fn mahc_comparable_to_full_ahc() {
    // Paper §4: MAHC matches or surpasses conventional AHC within a few
    // iterations. At this scale allow a modest deficit.
    let set = generate(&DatasetSpec::tiny(150, 8, 102));
    let backend = NativeBackend::new();
    let ahc = full_ahc(&set, &backend, 4, None, 0.25).unwrap();
    let mahc = MahcDriver::new(&set, cfg(3, Some(75), 5), &backend)
        .unwrap()
        .run()
        .unwrap();
    assert!(
        mahc.f_measure > ahc.f_measure - 0.15,
        "mahc {:.3} vs ahc {:.3}",
        mahc.f_measure,
        ahc.f_measure
    );
}

#[test]
fn final_k_approximates_stage_one_total() {
    // Paper claim 4: K = ΣKⱼ from the first stage is the final K.
    let set = generate(&DatasetSpec::tiny(120, 6, 103));
    let backend = NativeBackend::new();
    let res = MahcDriver::new(&set, cfg(3, Some(50), 4), &backend)
        .unwrap()
        .run()
        .unwrap();
    let stage1_total = res.history.records[0].total_clusters;
    // Final K is capped by the last medoid count; it must be in the
    // right ballpark of the stage-1 estimate.
    assert!(res.k > 0 && res.k <= stage1_total.max(1) + 1);
}

#[test]
fn metrics_sane_on_final_labels() {
    let set = generate(&DatasetSpec::tiny(100, 5, 104));
    let backend = NativeBackend::new();
    let res = MahcDriver::new(&set, cfg(2, Some(40), 4), &backend)
        .unwrap()
        .run()
        .unwrap();
    let truth = set.labels();
    let f = metrics::f_measure(&res.labels, &truth);
    let p = metrics::purity(&res.labels, &truth);
    let n = metrics::nmi(&res.labels, &truth);
    assert!((0.0..=1.0).contains(&f));
    assert!((0.0..=1.0).contains(&p));
    assert!((0.0..=1.0).contains(&n));
    assert!((f - res.f_measure).abs() < 1e-12);
}

#[test]
fn full_pipeline_on_xla_backend() {
    // The request path the architecture is about: MAHC+M with every DTW
    // going through the AOT Pallas kernel via PJRT.
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/ not built");
        return;
    }
    let rt = Runtime::new(Path::new("artifacts")).unwrap();
    let xla = XlaDtwBackend::new(&rt).unwrap();
    let mut spec = DatasetSpec::tiny(72, 5, 105);
    spec.feat_dim = 39;
    spec.len_range = (6, 60);
    let set = generate(&spec);

    let res_xla = MahcDriver::new(&set, cfg(3, Some(30), 3), &xla)
        .unwrap()
        .run()
        .unwrap();
    let native = NativeBackend::new();
    let res_nat = MahcDriver::new(&set, cfg(3, Some(30), 3), &native)
        .unwrap()
        .run()
        .unwrap();
    // Same algorithm over numerically-close backends: quality must agree
    // closely (exact label equality is not guaranteed under f32 noise).
    assert!(
        (res_xla.f_measure - res_nat.f_measure).abs() < 0.1,
        "xla F {:.3} vs native F {:.3}",
        res_xla.f_measure,
        res_nat.f_measure
    );
    for r in &res_xla.history.records {
        assert!(r.max_occupancy <= 30);
    }
}
