//! End-to-end pipeline integration: corpus → distance backend → MAHC(±M)
//! → metrics, on both backends, checking the paper's headline claims at
//! test scale.

use mahc::baselines::full_ahc;
use mahc::config::{AlgoConfig, Convergence, DatasetSpec, StreamConfig};
use mahc::corpus::generate;
use mahc::distance::NativeBackend;
use mahc::mahc::{MahcDriver, StreamingDriver};
use mahc::metrics;
use mahc::runtime::{Runtime, XlaDtwBackend};
use std::path::Path;

fn cfg(p0: usize, beta: Option<usize>, iters: usize) -> AlgoConfig {
    AlgoConfig {
        p0,
        beta,
        convergence: Convergence::FixedIters(iters),
        ..Default::default()
    }
}

#[test]
fn mahc_m_matches_mahc_f_measure_at_test_scale() {
    // Paper claim 2: size management costs no F-measure.
    let set = generate(&DatasetSpec::tiny(180, 9, 101));
    let backend = NativeBackend::new();
    let plain = MahcDriver::new(&set, cfg(4, None, 4), &backend)
        .unwrap()
        .run()
        .unwrap();
    let managed = MahcDriver::new(&set, cfg(4, Some(60), 4), &backend)
        .unwrap()
        .run()
        .unwrap();
    assert!(
        managed.f_measure > plain.f_measure - 0.1,
        "managed {:.3} vs plain {:.3}",
        managed.f_measure,
        plain.f_measure
    );
    // Claim 1: β bound held everywhere.
    for r in &managed.history.records {
        assert!(r.max_occupancy <= 60);
    }
}

#[test]
fn mahc_comparable_to_full_ahc() {
    // Paper §4: MAHC matches or surpasses conventional AHC within a few
    // iterations. At this scale allow a modest deficit.
    let set = generate(&DatasetSpec::tiny(150, 8, 102));
    let backend = NativeBackend::new();
    let ahc = full_ahc(&set, &backend, 4, None, 0.25).unwrap();
    let mahc = MahcDriver::new(&set, cfg(3, Some(75), 5), &backend)
        .unwrap()
        .run()
        .unwrap();
    assert!(
        mahc.f_measure > ahc.f_measure - 0.15,
        "mahc {:.3} vs ahc {:.3}",
        mahc.f_measure,
        ahc.f_measure
    );
}

#[test]
fn final_k_approximates_stage_one_total() {
    // Paper claim 4: K = ΣKⱼ from the first stage is the final K.
    let set = generate(&DatasetSpec::tiny(120, 6, 103));
    let backend = NativeBackend::new();
    let res = MahcDriver::new(&set, cfg(3, Some(50), 4), &backend)
        .unwrap()
        .run()
        .unwrap();
    let stage1_total = res.history.records[0].total_clusters;
    // Final K is capped by the last medoid count; it must be in the
    // right ballpark of the stage-1 estimate.
    assert!(res.k > 0 && res.k <= stage1_total.max(1) + 1);
}

#[test]
fn metrics_sane_on_final_labels() {
    let set = generate(&DatasetSpec::tiny(100, 5, 104));
    let backend = NativeBackend::new();
    let res = MahcDriver::new(&set, cfg(2, Some(40), 4), &backend)
        .unwrap()
        .run()
        .unwrap();
    let truth = set.labels();
    let f = metrics::f_measure(&res.labels, &truth);
    let p = metrics::purity(&res.labels, &truth);
    let n = metrics::nmi(&res.labels, &truth);
    assert!((0.0..=1.0).contains(&f));
    assert!((0.0..=1.0).contains(&p));
    assert!((0.0..=1.0).contains(&n));
    assert!((f - res.f_measure).abs() < 1e-12);
}

#[test]
fn streaming_single_shard_reproduces_batch_exactly() {
    // The streaming acceptance bar: one shard holding the whole corpus
    // runs the same episode with the same RNG stream as the batch
    // driver, so labels, K and F must be *bitwise* equal — with and
    // without the pair cache.
    let set = generate(&DatasetSpec::tiny(150, 8, 106));
    let backend = NativeBackend::new();
    for cache_bytes in [0usize, 8 << 20] {
        let mut config = cfg(3, Some(50), 4);
        config.cache_bytes = cache_bytes;
        let batch = MahcDriver::new(&set, config.clone(), &backend)
            .unwrap()
            .run()
            .unwrap();
        let stream =
            StreamingDriver::new(&set, StreamConfig::new(config, set.len()), &backend)
                .unwrap()
                .run()
                .unwrap();
        assert_eq!(stream.shards, 1);
        assert_eq!(
            stream.labels, batch.labels,
            "cache_bytes={cache_bytes}: labels diverged"
        );
        assert_eq!(stream.k, batch.k);
        assert_eq!(stream.f_measure, batch.f_measure);
    }
}

#[test]
fn streaming_multi_shard_obeys_beta_and_warms_the_cross_cache() {
    // A real stream: β must hold inside every shard's episode, every
    // object must come out labelled, later shards must carry medoids,
    // and the medoid × batch retirement rectangles
    // (`build_cross_cached`) must see nonzero cache hits — the pairs
    // were just computed by the episodes' condensed builds.
    let set = generate(&DatasetSpec::tiny(160, 8, 107));
    let backend = NativeBackend::new();
    let beta = 30;
    let mut algo = cfg(2, Some(beta), 3);
    algo.cache_bytes = 8 << 20;
    let stream = StreamingDriver::new(&set, StreamConfig::new(algo, 50), &backend)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(stream.shards, 4);
    assert_eq!(stream.history.records.len(), 4);
    for r in &stream.history.records {
        assert!(
            r.max_occupancy <= beta,
            "shard {} recorded occupancy {} > β={beta}",
            r.iteration,
            r.max_occupancy
        );
    }
    assert_eq!(stream.history.records[0].carried_medoids, 0);
    for r in &stream.history.records[1..] {
        assert!(r.carried_medoids > 0, "no medoids carried into shard");
    }
    assert_eq!(stream.labels.len(), set.len());
    assert!(stream.labels.iter().all(|&l| l < stream.k));
    assert!(
        stream.assign_cache.hits > 0,
        "retirement rectangles never hit the pair cache: {:?}",
        stream.assign_cache
    );
    // Quality stays in the plausible band for separable data.
    assert!(stream.f_measure > 0.3 && stream.f_measure <= 1.0);
}

#[test]
fn aggregated_pipeline_compresses_and_stays_close_in_quality() {
    // Stage-0 aggregation end to end: a data-derived radius must
    // actually shrink the pipeline input (compression ratio < 1), the
    // resolved labels must cover all N, and quality must stay in the
    // unaggregated run's neighbourhood.  ε is the corpus's 10th
    // pair-distance percentile, so only near-duplicates merge.
    use mahc::config::AggregateConfig;
    use mahc::corpus::Segment;
    use mahc::distance::build_condensed;

    let set = generate(&DatasetSpec::tiny(140, 7, 108));
    let backend = NativeBackend::new();
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let cond = build_condensed(&refs, &backend, 4).unwrap();
    let mut dists: Vec<f32> = cond.as_slice().to_vec();
    dists.sort_unstable_by(f32::total_cmp);
    let eps = dists[(dists.len() - 1) / 10];

    let plain = MahcDriver::new(&set, cfg(3, Some(50), 3), &backend)
        .unwrap()
        .run()
        .unwrap();
    let mut aggregated_cfg = cfg(3, Some(50), 3);
    aggregated_cfg.aggregate = AggregateConfig::new(eps);
    let agg = MahcDriver::new(&set, aggregated_cfg, &backend)
        .unwrap()
        .run()
        .unwrap();

    assert_eq!(agg.labels.len(), set.len());
    assert!(agg.labels.iter().all(|&l| l < agg.k));
    let r0 = &agg.history.records[0];
    assert!(r0.representatives >= 1 && r0.representatives <= set.len());
    assert!(r0.compression_ratio <= 1.0);
    assert!(r0.assignment_pairs > 0, "leader pass must have probed");
    assert!(
        agg.f_measure > plain.f_measure - 0.15,
        "aggregated F {:.3} too far below plain {:.3} (ratio {:.3})",
        agg.f_measure,
        plain.f_measure,
        r0.compression_ratio
    );
}

#[test]
fn full_pipeline_on_xla_backend() {
    // The request path the architecture is about: MAHC+M with every DTW
    // going through the AOT Pallas kernel via PJRT.
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/ not built");
        return;
    }
    let rt = Runtime::new(Path::new("artifacts")).unwrap();
    let xla = XlaDtwBackend::new(&rt).unwrap();
    let mut spec = DatasetSpec::tiny(72, 5, 105);
    spec.feat_dim = 39;
    spec.len_range = (6, 60);
    let set = generate(&spec);

    let res_xla = MahcDriver::new(&set, cfg(3, Some(30), 3), &xla)
        .unwrap()
        .run()
        .unwrap();
    let native = NativeBackend::new();
    let res_nat = MahcDriver::new(&set, cfg(3, Some(30), 3), &native)
        .unwrap()
        .run()
        .unwrap();
    // Same algorithm over numerically-close backends: quality must agree
    // closely (exact label equality is not guaranteed under f32 noise).
    assert!(
        (res_xla.f_measure - res_nat.f_measure).abs() < 0.1,
        "xla F {:.3} vs native F {:.3}",
        res_xla.f_measure,
        res_nat.f_measure
    );
    for r in &res_xla.history.records {
        assert!(r.max_occupancy <= 30);
    }
}
