//! Backend-parity conformance suite: the lane-parallel
//! [`BlockedBackend`] must be *indistinguishable* from the scalar
//! [`NativeBackend`] everywhere except wall-clock.
//!
//! Guarantees pinned here (and documented in EXPERIMENTS.md §Backends):
//!
//! * full-band pair distances are **bitwise identical** across dims,
//!   length ranges, lane-remainder shapes, and thread counts;
//! * banded pair distances are bitwise identical too (the blocked
//!   backend routes bands through the shared scalar kernel, so the
//!   banded deviation bound is zero ulp — tighter than the ≤16-ulp
//!   linkage-height caveat it is documented beside);
//! * the cached builders produce the same matrices *and the same
//!   PairCache hit/miss/eviction counters* under either backend (probe
//!   order is backend-invariant because both report the same
//!   `preferred_rows`);
//! * an end-to-end MAHC run — labels, K, F-measure bits, full
//!   occupancy/split telemetry — and a multi-shard streaming run are
//!   reproduced exactly under `--backend blocked`.
//!
//! The `MAHC_TEST_THREADS` / `MAHC_TEST_BACKEND` environment variables
//! extend the built-in matrix; the CI backend-matrix job sweeps them
//! over threads ∈ {1, 4} × backend ∈ {scalar, blocked}.

mod common;

use common::{assert_bitwise, backend_under_test, thread_matrix};
use mahc::config::{AlgoConfig, Convergence, DatasetSpec, StreamConfig};
use mahc::corpus::{generate, Segment, SegmentSet};
use mahc::distance::{
    build_condensed, build_condensed_cached, build_cross, BackendKind, BlockedBackend,
    PairwiseBackend, NativeBackend, PairCache,
};
use mahc::mahc::{MahcDriver, StreamingDriver};

fn corpus(n: usize, classes: usize, dim: usize, len_range: (usize, usize), seed: u64) -> SegmentSet {
    let mut spec = DatasetSpec::tiny(n, classes, seed);
    spec.feat_dim = dim;
    spec.len_range = len_range;
    generate(&spec)
}

#[test]
fn condensed_full_band_bitwise_across_dims_lengths_threads() {
    // Random generator corpora over a spread of dimensionalities and
    // length distributions (including the paper's 39-dim MFCC shape and
    // lengths straddling the 8-lane group width).
    for (dim, len_range, seed) in [
        (1usize, (2, 9), 101u64),
        (3, (6, 24), 102),
        (13, (6, 24), 103),
        (39, (8, 60), 104),
    ] {
        let set = corpus(42, 5, dim, len_range, seed);
        let refs: Vec<&Segment> = set.segments.iter().collect();
        let want = build_condensed(&refs, &NativeBackend::new(), 1).unwrap();
        for threads in thread_matrix(&[1, 2, 4]) {
            let got = build_condensed(&refs, &BlockedBackend::new(), threads).unwrap();
            assert_bitwise(
                want.as_slice(),
                got.as_slice(),
                &format!("dim={dim} threads={threads}"),
            );
        }
    }
}

#[test]
fn cross_rectangles_bitwise_including_lane_remainders() {
    let set = corpus(40, 4, 7, (3, 30), 105);
    let refs: Vec<&Segment> = set.segments.iter().collect();
    // Column counts around the 8-lane boundary: full groups, remainder
    // groups, a lone lane.
    for ny in [1usize, 5, 8, 9, 16, 23] {
        let (xs, ys) = (&refs[..7], &refs[7..7 + ny]);
        let want = build_cross(xs, ys, &NativeBackend::new(), 1).unwrap();
        for threads in thread_matrix(&[1, 2, 4]) {
            let got = build_cross(xs, ys, &BlockedBackend::new(), threads).unwrap();
            assert_bitwise(&want, &got, &format!("ny={ny} threads={threads}"));
        }
    }
}

#[test]
fn banded_pairs_bitwise_zero_ulp() {
    // Banded alignments share the scalar kernel, so parity is exact —
    // including the INFEASIBLE sentinel for out-of-band length ratios.
    let set = corpus(30, 4, 5, (2, 40), 106);
    let refs: Vec<&Segment> = set.segments.iter().collect();
    for band in [0usize, 1, 4, 16, 128] {
        let want = NativeBackend::banded(band)
            .pairwise(&refs[..10], &refs[10..])
            .unwrap();
        let got = BlockedBackend::banded(band)
            .pairwise(&refs[..10], &refs[10..])
            .unwrap();
        assert_bitwise(&want, &got, &format!("band={band}"));
    }
}

#[test]
fn cached_builds_and_hit_patterns_are_backend_invariant() {
    // Both backends report the same preferred_rows, so the cached
    // builder probes the cache in the same block order — the counters,
    // not just the matrices, must agree.
    let set = corpus(56, 5, 6, (4, 28), 107);
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let native = NativeBackend::new();
    let blocked = BlockedBackend::new();
    assert_eq!(native.preferred_rows(), blocked.preferred_rows());

    let want = build_condensed(&refs, &native, 1).unwrap();
    for budget in [1usize << 8, 1 << 20] {
        // Counters are compared on one thread: with eviction in play a
        // multi-threaded insert order is timing-dependent, so only the
        // single-threaded probe sequence is exactly reproducible.  (The
        // matrices are bitwise-stable at any thread count — pinned by
        // cache_determinism and the threads matrix above.)
        let cn = PairCache::with_capacity_bytes(budget);
        let cb = PairCache::with_capacity_bytes(budget);
        for pass in 0..3 {
            let a = build_condensed_cached(&refs, &native, 1, Some(&cn)).unwrap();
            let b = build_condensed_cached(&refs, &blocked, 1, Some(&cb)).unwrap();
            assert_bitwise(
                want.as_slice(),
                a.as_slice(),
                &format!("native budget={budget} pass={pass}"),
            );
            assert_bitwise(
                want.as_slice(),
                b.as_slice(),
                &format!("blocked budget={budget} pass={pass}"),
            );
        }
        assert_eq!(
            cn.stats(),
            cb.stats(),
            "budget={budget}: hit/miss/eviction counters must not depend on the backend"
        );
    }
}

fn mahc_cfg(threads: usize, cache_bytes: usize) -> AlgoConfig {
    AlgoConfig {
        p0: 3,
        beta: Some(40),
        convergence: Convergence::FixedIters(4),
        threads,
        cache_bytes,
        ..Default::default()
    }
}

#[test]
fn full_mahc_run_reproduced_exactly_under_blocked_backend() {
    let set = corpus(110, 6, 13, (6, 24), 108);
    let native = NativeBackend::new();
    let blocked = BlockedBackend::new();
    let want = MahcDriver::new(&set, mahc_cfg(2, 0), &native)
        .unwrap()
        .run()
        .unwrap();
    for threads in thread_matrix(&[1, 2, 4]) {
        let got = MahcDriver::new(&set, mahc_cfg(threads, 0), &blocked)
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(got.labels, want.labels, "threads={threads}");
        assert_eq!(got.k, want.k, "threads={threads}");
        assert_eq!(
            got.f_measure.to_bits(),
            want.f_measure.to_bits(),
            "threads={threads}"
        );
        for (a, b) in got.history.records.iter().zip(&want.history.records) {
            assert_eq!(a.subsets, b.subsets);
            assert_eq!(a.max_occupancy, b.max_occupancy);
            assert_eq!(a.min_occupancy, b.min_occupancy);
            assert_eq!(a.splits, b.splits);
            assert_eq!(a.total_clusters, b.total_clusters);
            assert_eq!(a.f_measure.to_bits(), b.f_measure.to_bits());
            assert_eq!(a.backend, "blocked");
            assert_eq!(b.backend, "native");
        }
    }
}

#[test]
fn streaming_run_reproduced_exactly_under_blocked_backend() {
    let set = corpus(120, 6, 13, (6, 24), 109);
    let native = NativeBackend::new();
    let blocked = BlockedBackend::new();
    let cfg = StreamConfig::new(mahc_cfg(2, 1 << 20), 40);
    let want = StreamingDriver::new(&set, cfg.clone(), &native)
        .unwrap()
        .run()
        .unwrap();
    let got = StreamingDriver::new(&set, cfg, &blocked)
        .unwrap()
        .run()
        .unwrap();
    assert!(want.shards > 1, "must exercise carry + retirement");
    assert_eq!(got.labels, want.labels);
    assert_eq!(got.k, want.k);
    assert_eq!(got.f_measure.to_bits(), want.f_measure.to_bits());
    assert_eq!(got.assign_cache, want.assign_cache);
}

#[test]
fn end_to_end_matrix_from_env() {
    // CI sweeps MAHC_TEST_BACKEND ∈ {scalar, blocked} ×
    // MAHC_TEST_THREADS ∈ {1, 4}; locally this defaults to one blocked
    // 2-thread cell.  Whatever the cell, the run must reproduce the
    // single-threaded scalar reference bitwise — with the pair cache on,
    // so scheduling, backend choice, and cache state are all exercised
    // against one another.
    let threads = *thread_matrix(&[2]).last().unwrap();
    let backend = backend_under_test(BackendKind::Blocked);

    let set = corpus(100, 5, 13, (6, 24), 110);
    let reference = MahcDriver::new(&set, mahc_cfg(1, 0), &NativeBackend::new())
        .unwrap()
        .run()
        .unwrap();
    let got = MahcDriver::new(&set, mahc_cfg(threads, 4 << 20), backend.as_ref())
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(
        got.labels,
        reference.labels,
        "{} t={threads}",
        backend.name()
    );
    assert_eq!(got.k, reference.k);
    assert_eq!(got.f_measure.to_bits(), reference.f_measure.to_bits());
}
