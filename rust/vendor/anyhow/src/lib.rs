//! Minimal vendored stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this crate
//! provides exactly the surface the workspace uses: [`Error`],
//! [`Result`], and the `anyhow!` / `bail!` / `ensure!` macros.  Like
//! the real `anyhow`, [`Error`] deliberately does *not* implement
//! `std::error::Error` — that is what makes the blanket `From` impl
//! (powering `?` on any std error type) coherent.

use std::fmt;

/// A type-erased error: a message plus an optional source chain,
/// flattened to strings at construction.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // Flatten the source chain into one line, matching the info
        // content of anyhow's `{:#}` format.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let v: i32 = s.parse()?; // std error converts via blanket From
        Ok(v)
    }

    #[test]
    fn question_mark_on_std_errors() {
        assert_eq!(parse("42").unwrap(), 42);
        let err = parse("nope").unwrap_err();
        assert!(err.to_string().contains("invalid digit"));
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky 7");

        fn g(ok: bool) -> Result<()> {
            ensure!(ok);
            Ok(())
        }
        assert!(g(true).is_ok());
        assert!(g(false).unwrap_err().to_string().contains("ok"));
        let e: Error = anyhow!("plain {}", "message");
        assert_eq!(format!("{e}"), "plain message");
        assert_eq!(format!("{e:?}"), "plain message");
    }
}
