//! API-compatible stub of the `xla-rs` PJRT bindings (see README.md).
//!
//! Mirrors the names and signatures `mahc::runtime::engine` consumes.
//! Construction of the PJRT client — the first call on every real code
//! path — returns [`Error`], so nothing downstream ever executes; the
//! remaining methods exist to satisfy the type checker and are
//! `unreachable` in practice (they too return errors rather than
//! panicking, defensively).

use std::fmt;

const STUB_MSG: &str = "xla stub: vendored placeholder bindings — point the workspace's `xla` \
     path dependency at a real xla-rs checkout to use the PJRT runtime";

/// Error type matching `xla-rs`'s surface: `Display` + `std::error::Error`.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn stub_err() -> Error {
    Error(STUB_MSG.to_string())
}

pub type Result<T> = std::result::Result<T, Error>;

/// Host literal (stub: carries nothing).
#[derive(Debug, Clone)]
pub struct Literal;

/// Element types accepted by [`Literal::vec1`].
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(stub_err())
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(stub_err())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(stub_err())
    }
}

#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(stub_err())
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-side buffer handle (stub).
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err())
    }
}

pub struct PjRtClient;

impl PjRtClient {
    /// Always fails in the stub: this is the first call on every real
    /// path, so downstream methods are never reached.
    pub fn cpu() -> Result<PjRtClient> {
        Err(stub_err())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err())
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_fails_with_the_stub_message() {
        assert!(PjRtClient::cpu().unwrap_err().to_string().contains("stub"));
        assert!(HloModuleProto::from_text_file("x").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
