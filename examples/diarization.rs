//! Speaker diarization over utterance embeddings — the embedding-space
//! workload the metric-generic distance API exists for.
//!
//! A session of utterance embeddings is generated with an *unknown*
//! speaker count (drawn from the corpus seed, as in real diarization),
//! then clustered by MAHC under the cosine metric with silhouette
//! model selection — no DTW, no variable-length alignment, same
//! multi-stage machinery.  The run reports the discovered speaker
//! count against the hidden truth, the diarization F-measure, and the
//! silhouette score the selector selected on, and dumps the run JSON
//! so the `metric` / `silhouette_score` telemetry fields are visible
//! end to end.
//!
//! ```text
//! cargo run --release --example diarization
//! ```
//!
//! CI hooks: the examples-smoke job runs this under
//! `MAHC_EXAMPLE_QUICK=1`, which shrinks the session.

use mahc::ahc::SelectionMethod;
use mahc::config::{AlgoConfig, Convergence};
use mahc::corpus::{diarization, DiarizationSpec};
use mahc::distance::{VectorBackend, VectorMetric};
use mahc::mahc::MahcDriver;

fn quick() -> bool {
    mahc::util::bench::env_flag("MAHC_EXAMPLE_QUICK")
}

fn main() -> anyhow::Result<()> {
    let utterances = if quick() { 120 } else { 600 };
    let spec = DiarizationSpec::tiny(utterances, 8, 23);
    let set = diarization(&spec);
    println!(
        "session: {} utterance embeddings (dim {}), speaker count hidden",
        set.len(),
        set.dim
    );

    let cfg = AlgoConfig {
        p0: if quick() { 3 } else { 5 },
        beta: Some(if quick() { 60 } else { 160 }),
        convergence: Convergence::FixedIters(if quick() { 3 } else { 5 }),
        selection: SelectionMethod::Silhouette,
        ..Default::default()
    };
    let backend = VectorBackend::blocked(VectorMetric::Cosine);
    let result = MahcDriver::new(&set, cfg, &backend)?.run()?;

    let last = result
        .history
        .records
        .last()
        .expect("run produced no iterations");
    println!(
        "diarization: {} speakers discovered (true: {}), F={:.4}",
        result.k, set.num_classes, result.f_measure
    );
    println!(
        "telemetry: metric={} silhouette_score={:.4} backend={}",
        last.metric, last.silhouette_score, last.backend
    );
    assert_eq!(last.metric, "cosine");
    assert!(
        last.silhouette_score > 0.0,
        "silhouette selection must score the evaluation cut"
    );
    assert!(
        result.f_measure > 0.5,
        "diarization degenerated: F = {}",
        result.f_measure
    );

    // The JSON the CLI's --out flag would write, proving the new
    // fields travel through the writer.
    let json = result.history.to_json().to_string();
    assert!(json.contains("\"metric\""));
    assert!(json.contains("\"silhouette_score\""));
    println!(
        "run JSON carries metric + silhouette_score ({} bytes)",
        json.len()
    );
    Ok(())
}
