//! Streaming subword discovery, demonstrated.
//!
//! A batch MAHC run needs the whole corpus before it can start; the
//! streaming driver clusters shard by shard, carrying the medoid set
//! forward, so peak matrix memory is bounded by β regardless of how
//! long the stream runs.  This example streams a corpus in four shard
//! sizes, prints the per-shard telemetry for one of them, and compares
//! quality and peak memory against the batch run — plus the single-
//! shard sanity check: one shard holding everything reproduces the
//! batch result bit for bit.
//!
//! ```text
//! cargo run --release --example streaming_discovery
//! ```

use mahc::config::{AlgoConfig, Convergence, DatasetSpec, StreamConfig};
use mahc::corpus::generate;
use mahc::distance::NativeBackend;
use mahc::mahc::{MahcDriver, StreamingDriver};

fn quick() -> bool {
    // The CI examples-smoke job sets this to keep the demo minutes low.
    mahc::util::bench::env_flag("MAHC_EXAMPLE_QUICK")
}

fn main() -> anyhow::Result<()> {
    let n = if quick() { 160 } else { 600 };
    let spec = DatasetSpec::tiny(n, 20, 88);
    let set = generate(&spec);
    let backend = NativeBackend::new();
    let beta = if quick() { 40 } else { 120 };
    let algo = AlgoConfig {
        p0: 3,
        beta: Some(beta),
        convergence: Convergence::FixedIters(3),
        cache_bytes: 32 << 20,
        ..Default::default()
    };

    let batch = MahcDriver::new(&set, algo.clone(), &backend)?.run()?;
    println!(
        "batch:  K={:<4} F={:.4} peak_matrix={:>8} B",
        batch.k,
        batch.f_measure,
        batch.history.peak_matrix_bytes()
    );

    println!("\nshard-size ablation (β={beta}):");
    println!("shard_size shards  K     F      peak_B  assign_hit%");
    let quarter = n.div_ceil(4);
    for shard_size in [n, n.div_ceil(2), quarter, n.div_ceil(8)] {
        let cfg = StreamConfig::new(algo.clone(), shard_size);
        let res = StreamingDriver::new(&set, cfg, &backend)?.run()?;
        println!(
            "{:>10} {:>6} {:>4} {:.4} {:>8} {:>10.1}",
            shard_size,
            res.shards,
            res.k,
            res.f_measure,
            res.history.peak_matrix_bytes(),
            res.assign_cache.hit_rate() * 100.0
        );
        if shard_size == quarter {
            println!("  per-shard telemetry at shard_size={quarter}:");
            println!("  shard carried  P_f maxOcc  K_tot   F");
            for r in &res.history.records {
                println!(
                    "  {:>5} {:>7} {:>4} {:>6} {:>6} {:.4}",
                    r.iteration,
                    r.carried_medoids,
                    r.subsets,
                    r.max_occupancy,
                    r.total_clusters,
                    r.f_measure
                );
                anyhow::ensure!(
                    r.max_occupancy <= beta,
                    "β bound violated in shard {}",
                    r.iteration
                );
            }
        }
    }

    // The single-shard stream is the batch run, bit for bit.
    let one = StreamingDriver::new(&set, StreamConfig::new(algo, set.len()), &backend)?.run()?;
    anyhow::ensure!(one.labels == batch.labels, "single-shard labels diverged");
    anyhow::ensure!(one.k == batch.k && one.f_measure == batch.f_measure);
    println!("\nsingle-shard stream reproduces the batch run: MATCH");
    Ok(())
}
