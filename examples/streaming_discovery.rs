//! Streaming subword discovery, demonstrated.
//!
//! A batch MAHC run needs the whole corpus before it can start; the
//! streaming driver clusters shard by shard, carrying the medoid set
//! forward, so peak matrix memory is bounded by β regardless of how
//! long the stream runs.  This example streams a corpus in four shard
//! sizes, prints the per-shard telemetry for one of them, and compares
//! quality and peak memory against the batch run — plus the single-
//! shard sanity check: one shard holding everything reproduces the
//! batch result bit for bit.
//!
//! ```text
//! cargo run --release --example streaming_discovery
//! ```

use mahc::config::{
    AggregateConfig, AlgoConfig, Convergence, DatasetSpec, RetireMode, StreamConfig,
};
use mahc::corpus::generate;
use mahc::distance::NativeBackend;
use mahc::mahc::{MahcDriver, StreamingDriver};

fn quick() -> bool {
    // The CI examples-smoke job sets this to keep the demo minutes low.
    mahc::util::bench::env_flag("MAHC_EXAMPLE_QUICK")
}

fn main() -> anyhow::Result<()> {
    let n = if quick() { 160 } else { 600 };
    let spec = DatasetSpec::tiny(n, 20, 88);
    let set = generate(&spec);
    let backend = NativeBackend::new();
    let beta = if quick() { 40 } else { 120 };
    let algo = AlgoConfig {
        p0: 3,
        beta: Some(beta),
        convergence: Convergence::FixedIters(3),
        cache_bytes: 32 << 20,
        ..Default::default()
    };

    let batch = MahcDriver::new(&set, algo.clone(), &backend)?.run()?;
    println!(
        "batch:  K={:<4} F={:.4} peak_matrix={:>8} B",
        batch.k,
        batch.f_measure,
        batch.history.peak_matrix_bytes()
    );

    println!("\nshard-size ablation (β={beta}):");
    println!("shard_size shards  K     F      peak_B  assign_hit%");
    let quarter = n.div_ceil(4);
    for shard_size in [n, n.div_ceil(2), quarter, n.div_ceil(8)] {
        let cfg = StreamConfig::new(algo.clone(), shard_size);
        let res = StreamingDriver::new(&set, cfg, &backend)?.run()?;
        println!(
            "{:>10} {:>6} {:>4} {:.4} {:>8} {:>10.1}",
            shard_size,
            res.shards,
            res.k,
            res.f_measure,
            res.history.peak_matrix_bytes(),
            res.assign_cache.hit_rate() * 100.0
        );
        if shard_size == quarter {
            println!("  per-shard telemetry at shard_size={quarter}:");
            println!("  shard carried  P_f maxOcc  K_tot   F");
            for r in &res.history.records {
                println!(
                    "  {:>5} {:>7} {:>4} {:>6} {:>6} {:.4}",
                    r.iteration,
                    r.carried_medoids,
                    r.subsets,
                    r.max_occupancy,
                    r.total_clusters,
                    r.f_measure
                );
                anyhow::ensure!(
                    r.max_occupancy <= beta,
                    "β bound violated in shard {}",
                    r.iteration
                );
            }
        }
    }

    // The single-shard stream is the batch run, bit for bit.
    let one = StreamingDriver::new(&set, StreamConfig::new(algo.clone(), set.len()), &backend)?
        .run()?;
    anyhow::ensure!(one.labels == batch.labels, "single-shard labels diverged");
    anyhow::ensure!(one.k == batch.k && one.f_measure == batch.f_measure);
    println!("\nsingle-shard stream reproduces the batch run: MATCH");

    // Aggregated stream, leader vs medoid retirement.  With a
    // quantile-derived ε the leader pass absorbs members before the
    // shards stream; at stream end `retire = Leader` forwards each
    // member to its leader's final cluster (the historical path), while
    // `retire = Medoid` reassigns it to the nearest *final* medoid.
    // Reassignment can only recover members a leader dragged across a
    // cluster boundary, so the medoid run's F-measure must never fall
    // below the leader run's — enforced here on every CI smoke run
    // (and pinned on a hand-provable fixture in
    // rust/tests/aggregation_quality.rs).
    let shard = n.div_ceil(2);
    let aggregated = AlgoConfig {
        aggregate: AggregateConfig::new(0.0).with_quantile(0.05),
        ..algo
    };
    let run_retire = |retire: RetireMode| {
        let mut cfg = aggregated.clone();
        cfg.retire = retire;
        StreamingDriver::new(&set, StreamConfig::new(cfg, shard), &backend)?.run()
    };
    let leader_run = run_retire(RetireMode::Leader)?;
    let medoid_run = run_retire(RetireMode::Medoid)?;
    let r0 = &leader_run.history.records[0];
    println!("\nretirement at q=0.05 ε (m={} representatives):", r0.representatives);
    println!(
        "  leader  K={:<4} F={:.4}\n  medoid  K={:<4} F={:.4}  (ΔF={:+.4})",
        leader_run.k,
        leader_run.f_measure,
        medoid_run.k,
        medoid_run.f_measure,
        medoid_run.f_measure - leader_run.f_measure
    );
    anyhow::ensure!(
        medoid_run.k == leader_run.k,
        "retirement must not change the cluster count"
    );
    anyhow::ensure!(
        medoid_run.f_measure >= leader_run.f_measure,
        "medoid retirement degraded F: {} < {}",
        medoid_run.f_measure,
        leader_run.f_measure
    );
    println!("medoid retirement never scores below leader forwarding: OK");
    Ok(())
}
