//! The memory guarantee — the paper's core claim, demonstrated.
//!
//! Runs plain MAHC and MAHC+M on a heavily skewed corpus (the Small-A
//! shape that drives Fig. 1's runaway growth) and tracks the occupancy
//! of the largest subset plus the peak condensed-matrix footprint,
//! showing that β caps both while leaving F-measure intact.
//!
//! ```text
//! cargo run --release --example memory_guarantee
//! ```

use mahc::config::{AlgoConfig, Convergence, DatasetSpec, NamedDataset};
use mahc::corpus::generate;
use mahc::distance::NativeBackend;
use mahc::mahc::MahcDriver;

fn mib(bytes: usize) -> f64 {
    bytes as f64 / (1 << 20) as f64
}

fn main() -> anyhow::Result<()> {
    // Skewed composition (Small Set A shape) at reduced scale.
    let spec = DatasetSpec::named(NamedDataset::SmallA, 0.1);
    let set = generate(&spec);
    let p0 = 4;
    let even = set.len() / p0;
    let beta = (even as f64 * 1.25).ceil() as usize;
    println!(
        "dataset {}: N={} classes={} | P0={p0} even share={even} β={beta}",
        set.name,
        set.len(),
        set.num_classes
    );
    println!(
        "full-AHC matrix would be {:.1} MiB; β caps any subset matrix at {:.2} MiB\n",
        mib(set.total_similarities() as usize * 4),
        mib(beta * (beta - 1) / 2 * 4)
    );

    let backend = NativeBackend::new();
    let base = AlgoConfig {
        p0,
        convergence: Convergence::FixedIters(6),
        ..Default::default()
    };

    let mut rows = Vec::new();
    for (name, beta_opt) in [("MAHC", None), ("MAHC+M", Some(beta))] {
        let cfg = AlgoConfig {
            beta: beta_opt,
            ..base.clone()
        };
        let res = MahcDriver::new(&set, cfg, &backend)?.run()?;
        println!("{name}:");
        println!("  iter  P_i  maxOcc  matrix(MiB)  F");
        for r in &res.history.records {
            println!(
                "  {:>4} {:>4} {:>7} {:>12.2}  {:.4}",
                r.iteration,
                r.subsets,
                r.max_occupancy,
                mib(r.peak_matrix_bytes),
                r.f_measure
            );
        }
        let peak_occ = res
            .history
            .records
            .iter()
            .map(|r| r.max_occupancy)
            .max()
            .unwrap_or(0);
        println!(
            "  peak occupancy {} ({}x even share), peak matrix {:.2} MiB, final F={:.4}\n",
            peak_occ,
            (peak_occ as f64 / even as f64 * 100.0).round() / 100.0,
            mib(res.history.peak_matrix_bytes()),
            res.f_measure
        );
        rows.push((name, peak_occ, res.history.peak_matrix_bytes(), res.f_measure));
    }

    let (_, occ_plain, bytes_plain, f_plain) = rows[0];
    let (_, occ_managed, bytes_managed, f_managed) = rows[1];
    println!("guarantee check:");
    println!(
        "  occupancy: plain peaked at {occ_plain}, managed never above β={beta} -> {}",
        if occ_managed <= beta { "HELD" } else { "VIOLATED" }
    );
    println!(
        "  memory:    plain {:.2} MiB vs managed {:.2} MiB ({}x reduction)",
        mib(bytes_plain),
        mib(bytes_managed),
        ((bytes_plain as f64 / bytes_managed.max(1) as f64) * 10.0).round() / 10.0
    );
    println!(
        "  quality:   F {:.4} (plain) vs {:.4} (managed), Δ = {:+.4}",
        f_plain,
        f_managed,
        f_managed - f_plain
    );
    anyhow::ensure!(occ_managed <= beta, "β guarantee violated");
    Ok(())
}
