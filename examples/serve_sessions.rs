//! Concurrent multi-stream serve mode, demonstrated.
//!
//! Several streaming-discovery sessions run at once over one worker
//! pool and one shared, budgeted pair cache.  The demo pins the three
//! serve-mode guarantees end to end:
//!
//! 1. **Bitwise isolation** — every session's labels, K and F-measure
//!    are identical to a sequential run of that session alone;
//! 2. **Budget enforcement** — the fleet cache's resident bytes never
//!    exceed the sum of the per-session budgets;
//! 3. **Panic robustness** — a session whose step job panics fails
//!    alone; the pool survives and the other sessions' outputs do not
//!    move a bit.
//!
//! CI hooks: the serve-smoke job runs this under `MAHC_EXAMPLE_QUICK=1`
//! and collects the fleet-throughput JSON fragment via
//! `MAHC_BENCH_JSON=path` into `BENCH_ci.json`.
//!
//! ```text
//! cargo run --release --example serve_sessions
//! ```

use std::sync::Arc;

use mahc::config::{AlgoConfig, Convergence, DatasetSpec, ServeConfig, StreamConfig};
use mahc::corpus::{generate, SegmentSet};
use mahc::distance::{PairwiseBackend, NativeBackend};
use mahc::mahc::{ServeDriver, SessionSpec, StreamingDriver};
use mahc::telemetry::Stopwatch;
use mahc::util::bench::{env_flag, write_json_report};
use mahc::util::json;

fn quick() -> bool {
    env_flag("MAHC_EXAMPLE_QUICK")
}

fn main() -> anyhow::Result<()> {
    let sessions = if quick() { 4 } else { 6 };
    let base_n = if quick() { 60 } else { 160 };
    let budget = 32 << 10;
    let backend: Arc<dyn PairwiseBackend + Send + Sync> = Arc::new(NativeBackend::new());

    // Distinct corpora: session i discovers subwords in its own stream.
    let sets: Vec<Arc<SegmentSet>> = (0..sessions)
        .map(|i| Arc::new(generate(&DatasetSpec::tiny(base_n + 12 * i, 5, 500 + i as u64))))
        .collect();
    let cfg_for = |_i: usize| {
        StreamConfig::new(
            AlgoConfig {
                p0: 2,
                beta: Some(if quick() { 24 } else { 48 }),
                convergence: Convergence::FixedIters(2),
                cache_bytes: budget,
                ..Default::default()
            },
            if quick() { 24 } else { 60 },
        )
    };
    let specs = |fault: Option<usize>| -> Vec<SessionSpec> {
        sets.iter()
            .enumerate()
            .map(|(i, set)| {
                let mut s = SessionSpec::new(&format!("s{i}"), Arc::clone(set), cfg_for(i));
                if fault == Some(i) {
                    s.panic_after_shards = Some(1);
                }
                s
            })
            .collect()
    };

    // Sequential baseline: each session alone, private caches.
    let t_seq = Stopwatch::start();
    let expected: Vec<_> = sets
        .iter()
        .enumerate()
        .map(|(i, set)| StreamingDriver::new(set, cfg_for(i), &NativeBackend::new())?.run())
        .collect::<anyhow::Result<_>>()?;
    let seq_wall = t_seq.elapsed().as_secs_f64();

    // The fleet: all sessions at once, one pool, one budgeted cache.
    let serve_cfg = ServeConfig {
        workers: 4,
        fleet_cap: sessions,
        queue_cap: 0,
        cache_bytes: 8 << 20,
    };
    let t_srv = Stopwatch::start();
    let report = ServeDriver::new(serve_cfg, Arc::clone(&backend))?.run(specs(None))?;
    let srv_wall = t_srv.elapsed().as_secs_f64();

    println!("session  status      K        F  shards       pairs");
    for s in &report.sessions {
        match &s.result {
            Ok(r) => println!(
                "{:<8} {:<7} {:>5} {:>8.4} {:>7} {:>11}",
                s.name, "ok", r.k, r.f_measure, r.shards, r.pairs
            ),
            Err(e) => println!("{:<8} {:<7} {e}", s.name, "failed"),
        }
    }
    anyhow::ensure!(report.completed() == sessions, "a session failed");
    for (out, exp) in report.sessions.iter().zip(&expected) {
        let got = out.result.as_ref().map_err(|e| anyhow::anyhow!("{e}"))?;
        anyhow::ensure!(
            got.labels == exp.labels
                && got.k == exp.k
                && got.f_measure.to_bits() == exp.f_measure.to_bits(),
            "{} diverged from its sequential run under concurrency",
            out.name
        );
    }
    println!("every session bitwise matches its sequential run: MATCH");

    let peak_cache = report.fleet.peak_cache_bytes();
    anyhow::ensure!(
        peak_cache <= sessions * budget,
        "fleet cache residency {peak_cache} B exceeds the {sessions} session budgets of {budget} B"
    );
    println!(
        "fleet cache: peak {peak_cache} B resident <= {} B budgeted across sessions",
        sessions * budget
    );
    let stalls = report.fleet.records.last().map_or(0, |r| r.stalls);
    println!(
        "fleet: peak active {}, {} stalls, {:.0} pairs/s; wall {:.2}s vs {:.2}s sequential",
        report.fleet.peak_active(),
        stalls,
        report.fleet.final_pairs_per_sec(),
        srv_wall,
        seq_wall
    );

    // Robustness: session 1's second step panics inside its pool job.
    // Its outcome is a captured failure; everyone else is untouched.
    let faulted = ServeDriver::new(
        ServeConfig {
            workers: 2,
            fleet_cap: sessions,
            queue_cap: 0,
            cache_bytes: 8 << 20,
        },
        backend,
    )?
    .run(specs(Some(1)))?;
    anyhow::ensure!(faulted.failed() == 1, "exactly one session must fail");
    for (i, (out, exp)) in faulted.sessions.iter().zip(&expected).enumerate() {
        if i == 1 {
            let msg = out.result.as_ref().err().map(String::as_str).unwrap_or("");
            anyhow::ensure!(
                msg.contains("injected session fault"),
                "unexpected failure: {msg}"
            );
            continue;
        }
        let got = out.result.as_ref().map_err(|e| anyhow::anyhow!("{e}"))?;
        anyhow::ensure!(
            got.labels == exp.labels && got.f_measure.to_bits() == exp.f_measure.to_bits(),
            "bystander {} perturbed by the faulted session",
            out.name
        );
    }
    println!("injected panic confined to its own session: MATCH");

    let pairs_total = report.fleet.records.last().map_or(0, |r| r.pairs_total);
    write_json_report(&json::obj(vec![
        ("quick", json::Json::Bool(quick())),
        ("sessions", json::num(sessions as f64)),
        ("completed", json::num(report.completed() as f64)),
        ("peak_active", json::num(report.fleet.peak_active() as f64)),
        ("peak_cache_bytes", json::num(peak_cache as f64)),
        ("stalls", json::num(stalls as f64)),
        ("pairs_total", json::num(pairs_total as f64)),
        (
            "fleet_pairs_per_sec",
            json::num(report.fleet.final_pairs_per_sec()),
        ),
        ("serve_wall_s", json::num(srv_wall)),
        ("sequential_wall_s", json::num(seq_wall)),
        (
            "faulted_run_bystanders_ok",
            json::Json::Bool(faulted.failed() == 1),
        ),
    ]))
    .map_err(|e| anyhow::anyhow!("writing MAHC_BENCH_JSON fragment: {e}"))?;
    Ok(())
}
