//! The cross-iteration pair cache, demonstrated.
//!
//! MAHC's refine step keeps stage-1 cluster members together, so most
//! within-subset DTW pairs recur from one iteration to the next.  This
//! example runs MAHC+M twice on the same corpus — cache off, then cache
//! on — and prints the per-iteration hit rate alongside wall-clock,
//! showing (a) identical clustering output and (b) the warm-up curve:
//! iteration 1 is all misses, later iterations are mostly hits.
//!
//! ```text
//! cargo run --release --example cache_warmup
//! ```

use std::time::Instant;

use mahc::config::{AlgoConfig, Convergence, DatasetSpec};
use mahc::corpus::generate;
use mahc::distance::NativeBackend;
use mahc::mahc::MahcDriver;

fn quick() -> bool {
    // The CI examples-smoke job sets this to keep the demo minutes low.
    mahc::util::bench::env_flag("MAHC_EXAMPLE_QUICK")
}

fn main() -> anyhow::Result<()> {
    let spec = DatasetSpec::tiny(if quick() { 180 } else { 700 }, 24, 77);
    let set = generate(&spec);
    let p0 = 4;
    let beta = ((set.len() as f64 / p0 as f64) * 1.25).ceil() as usize;
    let base = AlgoConfig {
        p0,
        beta: Some(beta),
        convergence: Convergence::FixedIters(5),
        ..Default::default()
    };
    let backend = NativeBackend::new();

    let t0 = Instant::now();
    let off = MahcDriver::new(&set, base.clone(), &backend)?.run()?;
    let wall_off = t0.elapsed();

    let budget = 64usize << 20;
    let cfg_on = AlgoConfig {
        cache_bytes: budget,
        ..base
    };
    let t0 = Instant::now();
    let on = MahcDriver::new(&set, cfg_on, &backend)?.run()?;
    let wall_on = t0.elapsed();

    println!(
        "N={} β={beta} cache budget={} MiB\n",
        set.len(),
        budget >> 20
    );
    println!("iter  hit%   hits    misses  evictions");
    for r in &on.history.records {
        println!(
            "{:>4} {:>5.1} {:>7} {:>9} {:>10}",
            r.iteration,
            r.cache.hit_rate() * 100.0,
            r.cache.hits,
            r.cache.misses,
            r.cache.evictions
        );
    }
    let total = on.history.cache_total();
    println!(
        "\nrun total: {:.1}% of pair distances served from cache",
        total.hit_rate() * 100.0
    );
    println!(
        "wall: {:.2}s uncached vs {:.2}s cached ({:.2}x)",
        wall_off.as_secs_f64(),
        wall_on.as_secs_f64(),
        wall_off.as_secs_f64() / wall_on.as_secs_f64().max(1e-9)
    );
    println!(
        "results identical: labels {} / K {} / F {:.4}",
        if on.labels == off.labels { "MATCH" } else { "MISMATCH" },
        on.k,
        on.f_measure
    );
    anyhow::ensure!(on.labels == off.labels, "cache changed the clustering");
    anyhow::ensure!(on.k == off.k && on.f_measure == off.f_measure);
    Ok(())
}
