//! Subword unit discovery — the paper's motivating ASR application
//! (§1): cluster unlabelled acoustic segments into an automatically
//! derived sub-word unit inventory, then build a pronunciation lexicon
//! by re-expressing "words" (triphone sequences) in the discovered
//! units.
//!
//! ```text
//! cargo run --release --example subword_discovery
//! ```

use mahc::config::{AlgoConfig, Convergence, DatasetSpec};
use mahc::corpus::generate;
use mahc::distance::NativeBackend;
use mahc::mahc::MahcDriver;
use mahc::metrics;
use mahc::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // Unlabelled speech stand-in: 900 segments from 30 triphone classes
    // (the labels exist only for evaluation, as with TIMIT).
    let spec = DatasetSpec::tiny(900, 30, 7);
    let set = generate(&spec);
    println!(
        "discovering sub-word units from {} unlabelled segments...",
        set.len()
    );

    let cfg = AlgoConfig {
        p0: 6,
        beta: Some(220),
        convergence: Convergence::SettledSubsets { max_iters: 8 },
        ..Default::default()
    };
    let backend = NativeBackend::new();
    let result = MahcDriver::new(&set, cfg, &backend)?.run()?;
    let truth = set.labels();
    println!(
        "inventory: {} units discovered (true classes: {}), F={:.4}, NMI={:.4}\n",
        result.k,
        set.num_classes,
        result.f_measure,
        metrics::nmi(&result.labels, &truth)
    );

    // --- unit inventory report: dominant class purity per unit ---------
    let mut unit_members: Vec<Vec<usize>> = vec![Vec::new(); result.k];
    for (seg, &u) in result.labels.iter().enumerate() {
        unit_members[u].push(seg);
    }
    let mut units: Vec<(usize, usize, f64)> = unit_members
        .iter()
        .enumerate()
        .map(|(u, members)| {
            let mut counts = std::collections::HashMap::new();
            for &m in members {
                *counts.entry(truth[m]).or_insert(0usize) += 1;
            }
            let dominant = counts.values().copied().max().unwrap_or(0);
            (u, members.len(), dominant as f64 / members.len().max(1) as f64)
        })
        .collect();
    units.sort_by(|a, b| b.1.cmp(&a.1));
    println!("largest discovered units (unit, size, purity):");
    for (u, size, purity) in units.iter().take(8) {
        println!("  unit_{u:<4} size={size:<5} purity={purity:.2}");
    }

    // --- pronunciation lexicon: synthetic words as unit strings --------
    // Build 12 "words", each a sequence of 2-4 triphone classes; their
    // pronunciations are the majority-unit transcription of each class.
    let mut class_to_unit = vec![0usize; set.num_classes];
    for c in 0..set.num_classes {
        let mut counts = std::collections::HashMap::new();
        for (seg, &t) in truth.iter().enumerate() {
            if t == c {
                *counts.entry(result.labels[seg]).or_insert(0usize) += 1;
            }
        }
        class_to_unit[c] = counts
            .into_iter()
            .max_by_key(|&(_, n)| n)
            .map(|(u, _)| u)
            .unwrap_or(0);
    }
    let mut rng = Rng::seed_from(99);
    println!("\nexample pronunciation lexicon (word -> discovered units):");
    for w in 0..12 {
        let len = rng.range(2, 5);
        let classes: Vec<usize> = (0..len).map(|_| rng.range(0, set.num_classes)).collect();
        let pron: Vec<String> = classes
            .iter()
            .map(|&c| format!("u{}", class_to_unit[c]))
            .collect();
        println!("  word_{w:<3} {}", pron.join(" "));
    }

    // A usable inventory: most mass should sit in reasonably pure units.
    let mass_pure: usize = units
        .iter()
        .filter(|&&(_, _, p)| p >= 0.5)
        .map(|&(_, s, _)| s)
        .sum();
    println!(
        "\n{:.0}% of segments live in units with ≥50% purity",
        100.0 * mass_pure as f64 / set.len() as f64
    );
    Ok(())
}
