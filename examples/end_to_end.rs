//! End-to-end driver: the full three-layer system on a real small
//! workload, proving all layers compose.
//!
//! Pipeline (Python never runs — everything below uses the AOT
//! artifacts through PJRT):
//!
//!   1. synthesise a triphone corpus as raw 16 kHz waveforms;
//!   2. extract 39-dim MFCC+Δ+ΔΔ features through the **AOT MFCC
//!      artifact** (Layer 2);
//!   3. cluster with MAHC+M where every DTW distance is computed by the
//!      **AOT Pallas wavefront kernel** (Layer 1) through the PJRT
//!      engine (Layer 3 hot path);
//!   4. report the paper's headline measurements: per-iteration Pᵢ /
//!      max-occupancy (the β guarantee), F-measure vs ground truth, and
//!      wall-clock vs the unmanaged MAHC baseline.
//!
//! ```text
//! make artifacts && cargo run --release --example end_to_end
//! ```
//!
//! Results from a reference run are recorded in EXPERIMENTS.md.

use std::path::Path;
use std::time::Instant;

use mahc::config::{AlgoConfig, Convergence, DatasetSpec};
use mahc::corpus::{generator, Segment, SegmentSet};
use mahc::distance::NativeBackend;
use mahc::mahc::MahcDriver;
use mahc::metrics;
use mahc::runtime::{mfcc_exec::MfccFrontend, Runtime, XlaDtwBackend};

fn main() -> anyhow::Result<()> {
    let t_start = Instant::now();
    let artifacts = std::env::var("MAHC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    anyhow::ensure!(
        Path::new(&artifacts).join("manifest.json").exists(),
        "artifacts not built — run `make artifacts` first"
    );
    let rt = Runtime::new(Path::new(&artifacts))?;
    println!("[1/4] PJRT engine up ({} artifacts)", rt.manifest().dtw.len() + rt.manifest().mfcc.len());

    // ---- 1. audio corpus ------------------------------------------------
    let mut spec = DatasetSpec::tiny(400, 16, 20260710);
    spec.feat_dim = 39;
    spec.len_range = (8, 60); // ≤ T=64 artifact bucket
    let t0 = Instant::now();
    let audio = generator::generate_audio(&spec, 0.01);
    let total_secs: f64 =
        audio.wavs.iter().map(|w| w.len() as f64).sum::<f64>() / 16_000.0;
    println!(
        "[2/4] synthesised {} waveform segments ({:.1} s of audio, {} classes) in {:.2}s",
        audio.wavs.len(),
        total_secs,
        audio.num_classes,
        t0.elapsed().as_secs_f64()
    );

    // ---- 2. MFCC through the AOT artifact -------------------------------
    let t0 = Instant::now();
    let fe = MfccFrontend::new(&rt)?;
    let wavs_f32: Vec<Vec<f32>> = audio
        .wavs
        .iter()
        .map(|w| w.iter().map(|&v| v as f32).collect())
        .collect();
    let feats = fe.extract(&wavs_f32)?;
    let segments: Vec<Segment> = feats
        .into_iter()
        .enumerate()
        .map(|(id, (len, feats))| Segment {
            id,
            class_id: audio.labels[id],
            len,
            dim: 39,
            feats,
        })
        .collect();
    let set = SegmentSet {
        name: audio.name.clone(),
        dim: 39,
        segments,
        num_classes: audio.num_classes,
    };
    set.validate()?;
    println!(
        "[3/4] AOT MFCC front-end: {} segments, {} frames total, in {:.2}s",
        set.len(),
        set.total_vectors(),
        t0.elapsed().as_secs_f64()
    );

    // ---- 3. MAHC+M with the AOT DTW kernel on the hot path --------------
    let beta = 140;
    let cfg = AlgoConfig {
        p0: 4,
        beta: Some(beta),
        convergence: Convergence::FixedIters(4),
        ..Default::default()
    };
    let xla = XlaDtwBackend::new(&rt)?;
    let t0 = Instant::now();
    let managed = MahcDriver::new(&set, cfg.clone(), &xla)?.run()?;
    let managed_wall = t0.elapsed();

    // Baseline: plain MAHC (no size management), same backend.
    let mut cfg_plain = cfg.clone();
    cfg_plain.beta = None;
    let t0 = Instant::now();
    let plain = MahcDriver::new(&set, cfg_plain, &xla)?.run()?;
    let plain_wall = t0.elapsed();

    // Sanity cross-check: the native backend must agree on quality.
    let native = NativeBackend::new();
    let nat = MahcDriver::new(&set, cfg, &native)?.run()?;

    // ---- 4. report -------------------------------------------------------
    println!("[4/4] results (all DTW on the AOT Pallas kernel via PJRT):\n");
    println!("MAHC+M  (β={beta}):");
    println!("  iter  P_i  maxOcc  splits  F");
    for r in &managed.history.records {
        println!(
            "  {:>4} {:>4} {:>7} {:>7}  {:.4}",
            r.iteration, r.subsets, r.max_occupancy, r.splits, r.f_measure
        );
        assert!(r.max_occupancy <= beta, "β guarantee violated");
    }
    let truth = set.labels();
    println!(
        "  final: K={} F={:.4} purity={:.4} NMI={:.4} wall={:.2}s",
        managed.k,
        managed.f_measure,
        metrics::purity(&managed.labels, &truth),
        metrics::nmi(&managed.labels, &truth),
        managed_wall.as_secs_f64()
    );
    println!(
        "\nplain MAHC: F={:.4} peak occupancy={} wall={:.2}s",
        plain.f_measure,
        plain
            .history
            .records
            .iter()
            .map(|r| r.max_occupancy)
            .max()
            .unwrap_or(0),
        plain_wall.as_secs_f64()
    );
    println!("native-backend cross-check: F={:.4}", nat.f_measure);
    println!(
        "\nheadline: β={beta} held on every iteration; ΔF(managed − plain) = {:+.4}; \
         total {:.1}s",
        managed.f_measure - plain.f_measure,
        t_start.elapsed().as_secs_f64()
    );
    anyhow::ensure!(
        (managed.f_measure - plain.f_measure).abs() < 0.15,
        "size management should not change F materially"
    );
    anyhow::ensure!(
        (managed.f_measure - nat.f_measure).abs() < 0.15,
        "backends should agree on quality"
    );
    Ok(())
}
