//! Stage-0 aggregation, demonstrated: compression ratio vs quality
//! across a data-derived ε sweep, the quantile-derived radius, and the
//! probe-engine modes (per-row, rectangle-batched, batched + tree).
//!
//! The leader pass groups segments within DTW radius ε of an earlier-
//! seen representative, the drivers cluster only the m representatives,
//! and members resolve to final clusters through their leader — so the
//! knob trades pipeline input size against fidelity.  The two ends of
//! the sweep are exact: ε = 0 reproduces the unaggregated run bitwise,
//! and ε beyond the largest pair distance collapses the corpus onto a
//! single representative.  In between, small radii merge near-
//! duplicates and barely move F while already shrinking the input.
//! Instead of guessing an absolute ε, `--aggregate-quantile q` derives
//! it from the corpus itself — shown here to match the sweep's own
//! quantile bit for bit.
//!
//! ```text
//! cargo run --release --example aggregation_sweep
//! ```
//!
//! Set `MAHC_EXAMPLE_QUICK=1` (the CI examples-smoke job does) to run
//! on a smaller corpus.

use mahc::aggregate::{aggregate, derive_epsilon, quantile_of_sorted};
use mahc::config::{AggregateConfig, AlgoConfig, Convergence, DatasetSpec, StreamConfig};
use mahc::corpus::{generate, Segment};
use mahc::distance::{build_condensed, NativeBackend};
use mahc::mahc::{MahcDriver, StreamingDriver};

fn quick() -> bool {
    mahc::util::bench::env_flag("MAHC_EXAMPLE_QUICK")
}

fn main() -> anyhow::Result<()> {
    let n = if quick() { 100 } else { 260 };
    let set = generate(&DatasetSpec::tiny(n, 10, 91));
    let backend = NativeBackend::new();

    // Data-derived radii: pair-distance quantiles of this corpus.
    let refs: Vec<&Segment> = set.segments.iter().collect();
    let cond = build_condensed(&refs, &backend, 4)?;
    let mut dists: Vec<f32> = cond.as_slice().to_vec();
    dists.sort_unstable_by(f32::total_cmp);
    let quantile = |q: f64| quantile_of_sorted(&dists, q);

    let algo = AlgoConfig {
        p0: 3,
        beta: Some((n as f64 / 3.0 * 1.25).ceil() as usize),
        convergence: Convergence::FixedIters(3),
        ..Default::default()
    };
    let plain = MahcDriver::new(&set, algo.clone(), &backend)?.run()?;
    println!(
        "N={n}  unaggregated: K={} F={:.4}\n",
        plain.k, plain.f_measure
    );

    println!("      ε       reps    m/N     K      F      ΔF%");
    for (tag, eps) in [
        ("ε=0 ", 0.0),
        ("p05 ", quantile(0.05)),
        ("p10 ", quantile(0.10)),
        ("p25 ", quantile(0.25)),
        ("p50 ", quantile(0.50)),
    ] {
        let cfg = AlgoConfig {
            aggregate: AggregateConfig::new(eps),
            ..algo.clone()
        };
        let res = MahcDriver::new(&set, cfg, &backend)?.run()?;
        anyhow::ensure!(res.labels.len() == n, "labels must cover the corpus");
        let (reps, ratio) = match res.history.records.first() {
            Some(r) if r.representatives > 0 => (r.representatives, r.compression_ratio),
            _ => (n, 1.0),
        };
        let delta = (res.f_measure - plain.f_measure) / plain.f_measure * 100.0;
        println!(
            "{tag} {eps:>8.3} {reps:>6} {ratio:.3} {:>5} {:.4} {delta:>6.1}",
            res.k, res.f_measure
        );
        if eps == 0.0 {
            // The zero-risk end of the sweep, bit for bit.
            anyhow::ensure!(res.labels == plain.labels, "ε=0 diverged from plain");
            anyhow::ensure!(res.k == plain.k);
            anyhow::ensure!(res.f_measure.to_bits() == plain.f_measure.to_bits());
        }
    }

    // The other exact end: a radius past every pair distance leaves a
    // single representative, whatever the corpus.
    let d_max = *dists.last().unwrap();
    let top = aggregate(
        &set,
        &AggregateConfig::new(d_max * 1.01),
        &backend,
        4,
        None,
    )?;
    anyhow::ensure!(top.reps() == 1, "ε past max distance must collapse to 1");
    println!(
        "\nε={:.3} (past max pair distance): 1 representative, ratio {:.4}",
        d_max * 1.01,
        top.compression_ratio()
    );

    // Quantile-derived ε: with a sample covering the corpus, the
    // product estimator reproduces this harness's own p25 bit for bit.
    let seed = AggregateConfig::default().quantile_seed;
    let (eps_q, _) = derive_epsilon(&set, 0.25, n, seed, &backend, 4, None)?;
    anyhow::ensure!(
        eps_q.to_bits() == quantile(0.25).to_bits(),
        "full-sample quantile estimate must be exact"
    );
    println!("quantile-derived ε (q=0.25): {eps_q:.3} — matches the sweep's p25 bitwise");

    // Probe-engine modes at p25: per-row reference, rectangle-batched,
    // batched + two-level tree.  Identical groups for the first two —
    // the rectangle only changes dispatch shape — and fewer probe DTWs
    // than leaders × segments for the tree.
    let eps25 = quantile(0.25);
    let serial_cfg = AggregateConfig::new(eps25).with_batch_rows(1);
    let batched_cfg = AggregateConfig::new(eps25).with_batch_rows(64);
    let tree_cfg = batched_cfg.with_tree(3.0, 2);
    let serial = aggregate(&set, &serial_cfg, &backend, 4, None)?;
    let batched = aggregate(&set, &batched_cfg, &backend, 4, None)?;
    let tree = aggregate(&set, &tree_cfg, &backend, 4, None)?;
    anyhow::ensure!(batched.rep_ids == serial.rep_ids, "batched parity broke");
    anyhow::ensure!(batched.members == serial.members, "batched parity broke");
    anyhow::ensure!(
        tree.probe_pairs < tree.reps() * n,
        "tree must probe fewer pairs than leaders × segments"
    );
    println!("\nprobe engine at p25 (m={} leaders):", serial.reps());
    for (tag, a) in [("per-row", &serial), ("batched", &batched), ("tree", &tree)] {
        println!(
            "  {tag:<8} probes={:<6} rounds={:<4} rect={}x{} supers={}",
            a.probe_pairs, a.probe_rounds, a.rect_rows, a.rect_cols, a.super_leaders
        );
    }

    // Aggregation composes with the streaming driver: the stream is a
    // stream of representatives, members follow their leader.
    let stream_cfg = StreamConfig::new(
        AlgoConfig {
            aggregate: AggregateConfig::new(quantile(0.10)),
            ..algo
        },
        n.div_ceil(3),
    );
    let stream = StreamingDriver::new(&set, stream_cfg, &backend)?.run()?;
    anyhow::ensure!(stream.labels.len() == n);
    println!(
        "\nstreamed over representatives: {} shards, K={} F={:.4}",
        stream.shards, stream.k, stream.f_measure
    );
    println!("\nε=0 reproduces the unaggregated run bitwise: MATCH");
    Ok(())
}
