//! Quickstart: cluster a small synthetic triphone corpus with MAHC+M
//! and evaluate against ground truth.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Uses the native DTW backend so it works without artifacts; see
//! `examples/end_to_end.rs` for the full AOT/PJRT pipeline.

use mahc::config::{AlgoConfig, Convergence, DatasetSpec};
use mahc::corpus::{generate, CompositionStats};
use mahc::distance::NativeBackend;
use mahc::mahc::MahcDriver;
use mahc::metrics;

fn main() -> anyhow::Result<()> {
    // 1. A small corpus: 600 variable-length MFCC segments, 20 classes.
    let spec = DatasetSpec::tiny(600, 20, 42);
    let set = generate(&spec);
    println!("corpus: {}", CompositionStats::of(&set).table_row());

    // 2. Configure Algorithm 1: 4 initial subsets, β = 200 (the memory
    //    bound: no subset — hence no distance matrix — may exceed it).
    let cfg = AlgoConfig {
        p0: 4,
        beta: Some(200),
        convergence: Convergence::FixedIters(5),
        ..Default::default()
    };

    // 3. Run MAHC+M over the native DTW backend.
    let backend = NativeBackend::new();
    let result = MahcDriver::new(&set, cfg, &backend)?.run()?;

    // 4. Inspect: per-iteration telemetry + final quality.
    println!("\niter  P_i  maxOcc  splits  F");
    for r in &result.history.records {
        println!(
            "{:>4} {:>4} {:>7} {:>7}  {:.4}",
            r.iteration, r.subsets, r.max_occupancy, r.splits, r.f_measure
        );
    }
    let truth = set.labels();
    println!(
        "\nfinal: K={}  F={:.4}  purity={:.4}  NMI={:.4}",
        result.k,
        result.f_measure,
        metrics::purity(&result.labels, &truth),
        metrics::nmi(&result.labels, &truth),
    );
    println!(
        "peak distance-matrix memory: {:.2} MiB (β bound: {:.2} MiB)",
        result.history.peak_matrix_bytes() as f64 / (1 << 20) as f64,
        (200 * 199 / 2 * 4) as f64 / (1 << 20) as f64
    );
    Ok(())
}
