//! Backend showdown: the same MAHC+M run under the scalar and the
//! lane-parallel blocked DTW backends — identical clustering, different
//! wall-clock.
//!
//! ```text
//! cargo run --release --example backend_showdown
//! ```
//!
//! Demonstrates the backend-invariance guarantee end to end (labels, K
//! and F-measure bits must match; the per-iteration telemetry names the
//! serving backend and its pairs/sec), then prints the throughput each
//! backend achieved per iteration.

use mahc::config::{AlgoConfig, Convergence, DatasetSpec};
use mahc::corpus::generate;
use mahc::distance::{BlockedBackend, PairwiseBackend, NativeBackend};
use mahc::mahc::{MahcDriver, MahcResult};

fn quick() -> bool {
    // The CI examples-smoke job sets this to keep the demo minutes low.
    mahc::util::bench::env_flag("MAHC_EXAMPLE_QUICK")
}

fn run(set: &mahc::corpus::SegmentSet, backend: &dyn PairwiseBackend) -> anyhow::Result<MahcResult> {
    let cfg = AlgoConfig {
        p0: 4,
        beta: Some(if quick() { 60 } else { 150 }),
        convergence: Convergence::FixedIters(4),
        ..Default::default()
    };
    MahcDriver::new(set, cfg, backend)?.run()
}

fn main() -> anyhow::Result<()> {
    let mut spec = DatasetSpec::tiny(if quick() { 140 } else { 400 }, 16, 77);
    spec.feat_dim = 39;
    let set = generate(&spec);

    let scalar = run(&set, &NativeBackend::new())?;
    let blocked = run(&set, &BlockedBackend::new())?;

    // Same bits, whichever backend served the distances.
    assert_eq!(scalar.labels, blocked.labels);
    assert_eq!(scalar.k, blocked.k);
    assert_eq!(scalar.f_measure.to_bits(), blocked.f_measure.to_bits());
    println!(
        "identical clustering under both backends: K={} F={:.4}\n",
        scalar.k, scalar.f_measure
    );

    println!("iter   native pairs/s  blocked pairs/s  speedup");
    for (a, b) in scalar
        .history
        .records
        .iter()
        .zip(&blocked.history.records)
    {
        let speedup = if a.pairs_per_sec > 0.0 {
            b.pairs_per_sec / a.pairs_per_sec
        } else {
            0.0
        };
        println!(
            "{:>4} {:>16.0} {:>16.0} {:>7.2}x",
            a.iteration, a.pairs_per_sec, b.pairs_per_sec, speedup
        );
    }
    let (ws, wb) = (
        scalar.history.wall_series().iter().sum::<f64>(),
        blocked.history.wall_series().iter().sum::<f64>(),
    );
    println!("\ntotal wall: native {ws:.2}s, blocked {wb:.2}s");
    Ok(())
}
