"""Layer-2 JAX compute graphs (build-time only; never on the request path).

Two graphs are AOT-lowered by aot.py and executed from the Rust
coordinator through the PJRT CPU client:

  * pairwise_dtw  — a (Bx, By) tile of the DTW distance matrix, calling
    the Layer-1 Pallas kernel (kernels/dtw.py).  The Rust distance
    builder tiles every subset's condensed matrix over this executable.
  * mfcc_frontend — the HTK-style acoustic front-end of paper §6.1:
    waveform (B, S) -> (B, T, 39) MFCC + logE + Δ + ΔΔ.  Pure jnp; XLA
    fuses the whole chain into one executable.

Both are pinned against the numpy oracles in kernels/ref.py by
python/tests/.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import dtw as dtw_kernel
from .kernels import ref

# ---------------------------------------------------------------------------
# pairwise DTW tile
# ---------------------------------------------------------------------------


def pairwise_dtw(x, y, lenx, leny, *, band: int | None = None):
    """(Bx,T,D) x (By,T,D) -> (Bx,By) normalised DTW distances (1-tuple).

    Returned as a 1-tuple because aot.py lowers with return_tuple=True
    and the Rust side unwraps with to_tuple1().
    """
    return (dtw_kernel.dtw_tile(x, y, lenx, leny, band=band),)


# ---------------------------------------------------------------------------
# MFCC front-end (mirrors kernels/ref.py in f32)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _mel_fb_f32() -> np.ndarray:
    return ref.mel_filterbank().astype(np.float32)


@functools.lru_cache(maxsize=None)
def _dct_f32() -> np.ndarray:
    return ref.dct_matrix().astype(np.float32)


@functools.lru_cache(maxsize=None)
def _hamming_f32() -> np.ndarray:
    return ref.hamming().astype(np.float32)


def _frame(wav):
    """(B, S) -> (B, T, FRAME_LEN) strided framing via gather."""
    s = wav.shape[-1]
    t = 1 + (s - ref.FRAME_LEN) // ref.FRAME_HOP
    starts = jnp.arange(t) * ref.FRAME_HOP  # (T,)
    idx = starts[:, None] + jnp.arange(ref.FRAME_LEN)[None, :]  # (T, L)
    return wav[:, idx]  # (B, T, L)


def _delta(feat, lens):
    """HTK regression deltas over the time axis with edge replication at
    the *true* segment end.

    feat: (B, T, F); lens: (B,) i32 true frame counts.  Each lane's
    forward lookups clamp to its own last real frame (lens-1), matching
    ref.delta applied to the unpadded signal — without this, padded
    silence frames bleed into the last delta_win*2 frames of every
    segment (caught by the rust artifact_crosscheck test).
    """
    t = feat.shape[1]
    denom = 2.0 * sum(th * th for th in range(1, ref.DELTA_WIN + 1))
    ts = jnp.arange(t)[None, :]  # (1, T)
    last = (lens - 1).astype(jnp.int32)[:, None]  # (B, 1)
    acc = jnp.zeros_like(feat)
    for th in range(1, ref.DELTA_WIN + 1):
        idx_f = jnp.minimum(ts + th, last)  # (B, T) per-lane clamp
        idx_b = jnp.maximum(ts - th, 0)
        idx_b = jnp.minimum(idx_b, last)  # beyond-len frames irrelevant
        fwd = jnp.take_along_axis(feat, idx_f[..., None], axis=1)
        bwd = jnp.take_along_axis(feat, jnp.broadcast_to(idx_b, idx_f.shape)[..., None], axis=1)
        acc = acc + th * (fwd - bwd)
    return acc / denom


def mfcc_frontend(wav, lens):
    """(B, S) f32 waveform + (B,) i32 frame counts ->
    ((B, T, 39) f32,) MFCC+logE+Δ+ΔΔ."""
    # Pre-emphasis.
    first = wav[:, :1] * (1.0 - ref.PREEMPH)
    rest = wav[:, 1:] - ref.PREEMPH * wav[:, :-1]
    pre = jnp.concatenate([first, rest], axis=-1)

    frames = _frame(pre) * jnp.asarray(_hamming_f32())  # (B, T, L)
    spec = jnp.fft.rfft(frames, n=ref.NFFT, axis=-1)
    power = jnp.abs(spec) ** 2  # (B, T, NFFT//2+1)

    mel = jnp.log(jnp.maximum(power @ jnp.asarray(_mel_fb_f32()).T, ref.FLOOR))
    ceps = mel @ jnp.asarray(_dct_f32()).T  # (B, T, 12)
    log_e = jnp.log(jnp.maximum(jnp.sum(frames * frames, axis=-1), ref.FLOOR))

    base = jnp.concatenate([ceps, log_e[..., None]], axis=-1)  # (B, T, 13)
    d1 = _delta(base, lens)
    d2 = _delta(d1, lens)
    return (jnp.concatenate([base, d1, d2], axis=-1),)  # (B, T, 39)


def mfcc_num_frames(num_samples: int) -> int:
    return 1 + (num_samples - ref.FRAME_LEN) // ref.FRAME_HOP
