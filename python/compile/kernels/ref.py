"""Pure-numpy correctness oracles for the Layer-1/Layer-2 graphs.

Everything here is deliberately written as plain, slow, loop-based numpy
so there is nothing clever to be wrong: the Pallas kernel (kernels/dtw.py)
and the JAX MFCC front-end (compile/model.py) are both asserted against
these in python/tests/.

The same DTW semantics are implemented a third time in Rust
(rust/src/dtw/) — integration tests check rust-vs-artifact agreement, so
all three implementations are pinned to this definition:

  * step set {(1,0), (0,1), (1,1)}, unweighted;
  * local distance Euclidean;
  * distance = cumulative cost at (lx-1, ly-1) / (lx + ly);
  * optional Sakoe-Chiba band radius (|i-j| > band forbidden).
"""

from __future__ import annotations

import numpy as np

INF = float("inf")


# --------------------------------------------------------------------------
# DTW
# --------------------------------------------------------------------------


def dtw_single(x: np.ndarray, y: np.ndarray, band: int | None = None) -> float:
    """Normalised DTW distance between two (len, D) float sequences."""
    lx, ly = len(x), len(y)
    assert lx >= 1 and ly >= 1
    cost = np.full((lx, ly), INF, dtype=np.float64)
    for i in range(lx):
        for j in range(ly):
            if band is not None and abs(i - j) > band:
                continue
            d = float(np.sqrt(np.sum((x[i].astype(np.float64) - y[j]) ** 2)))
            if i == 0 and j == 0:
                best = 0.0
            else:
                best = INF
                if i > 0:
                    best = min(best, cost[i - 1, j])
                if j > 0:
                    best = min(best, cost[i, j - 1])
                if i > 0 and j > 0:
                    best = min(best, cost[i - 1, j - 1])
            cost[i, j] = d + best
    return float(cost[lx - 1, ly - 1]) / (lx + ly)


def dtw_pairwise(
    x: np.ndarray,
    y: np.ndarray,
    lenx: np.ndarray,
    leny: np.ndarray,
    band: int | None = None,
) -> np.ndarray:
    """Oracle for dtw_tile: (Bx,T,D) x (By,T,D) -> (Bx,By)."""
    out = np.zeros((x.shape[0], y.shape[0]), dtype=np.float64)
    for p in range(x.shape[0]):
        for q in range(y.shape[0]):
            out[p, q] = dtw_single(x[p, : lenx[p]], y[q, : leny[q]], band=band)
    return out


# --------------------------------------------------------------------------
# MFCC front-end (HTK-style, matching compile/model.py and rust/src/dsp/)
# --------------------------------------------------------------------------

SAMPLE_RATE = 16_000
FRAME_LEN = 160  # 10 ms
FRAME_HOP = 80  # 5 ms  (50% overlap, paper §6.1)
NFFT = 256
N_MELS = 26
N_CEPS = 12
PREEMPH = 0.97
DELTA_WIN = 2
FLOOR = 1.0e-10


def hz_to_mel(f):
    return 2595.0 * np.log10(1.0 + np.asarray(f, dtype=np.float64) / 700.0)


def mel_to_hz(m):
    return 700.0 * (10.0 ** (np.asarray(m, dtype=np.float64) / 2595.0) - 1.0)


def mel_filterbank(
    n_mels: int = N_MELS, nfft: int = NFFT, sr: int = SAMPLE_RATE
) -> np.ndarray:
    """(n_mels, nfft//2 + 1) triangular filters, HTK-style mel spacing."""
    lo, hi = hz_to_mel(0.0), hz_to_mel(sr / 2.0)
    mel_pts = np.linspace(lo, hi, n_mels + 2)
    hz_pts = mel_to_hz(mel_pts)
    bins_hz = np.arange(nfft // 2 + 1) * (sr / nfft)
    fb = np.zeros((n_mels, nfft // 2 + 1), dtype=np.float64)
    for m in range(n_mels):
        left, center, right = hz_pts[m], hz_pts[m + 1], hz_pts[m + 2]
        up = (bins_hz - left) / max(center - left, 1e-12)
        down = (right - bins_hz) / max(right - center, 1e-12)
        fb[m] = np.maximum(0.0, np.minimum(up, down))
    return fb


def dct_matrix(n_ceps: int = N_CEPS, n_mels: int = N_MELS) -> np.ndarray:
    """(n_ceps, n_mels) DCT-II rows 1..n_ceps with HTK sqrt(2/N) scaling."""
    m = np.arange(n_mels, dtype=np.float64)
    rows = []
    for k in range(1, n_ceps + 1):
        rows.append(np.sqrt(2.0 / n_mels) * np.cos(np.pi * k * (m + 0.5) / n_mels))
    return np.stack(rows)


def frame_signal(wav: np.ndarray) -> np.ndarray:
    """(S,) -> (T, FRAME_LEN), T = 1 + (S - FRAME_LEN) // FRAME_HOP."""
    s = len(wav)
    t = 1 + (s - FRAME_LEN) // FRAME_HOP
    return np.stack([wav[i * FRAME_HOP : i * FRAME_HOP + FRAME_LEN] for i in range(t)])


def hamming(n: int = FRAME_LEN) -> np.ndarray:
    return 0.54 - 0.46 * np.cos(2.0 * np.pi * np.arange(n) / (n - 1))


def delta(feat: np.ndarray, win: int = DELTA_WIN) -> np.ndarray:
    """HTK regression deltas with edge replication padding."""
    t = feat.shape[0]
    denom = 2.0 * sum(th * th for th in range(1, win + 1))
    out = np.zeros_like(feat)
    for i in range(t):
        acc = np.zeros(feat.shape[1], dtype=feat.dtype)
        for th in range(1, win + 1):
            fwd = feat[min(i + th, t - 1)]
            bwd = feat[max(i - th, 0)]
            acc += th * (fwd - bwd)
        out[i] = acc / denom
    return out


def mfcc_single(wav: np.ndarray) -> np.ndarray:
    """(S,) waveform -> (T, 39) MFCC + logE + deltas + delta-deltas."""
    wav = np.asarray(wav, dtype=np.float64)
    pre = np.concatenate([[wav[0] * (1.0 - PREEMPH)], wav[1:] - PREEMPH * wav[:-1]])
    frames = frame_signal(pre) * hamming()
    spec = np.fft.rfft(frames, n=NFFT, axis=-1)
    power = np.abs(spec) ** 2
    fb = mel_filterbank()
    mel = np.log(np.maximum(power @ fb.T, FLOOR))
    ceps = mel @ dct_matrix().T  # (T, 12)
    log_e = np.log(np.maximum(np.sum(frames**2, axis=-1), FLOOR))  # (T,)
    base = np.concatenate([ceps, log_e[:, None]], axis=-1)  # (T, 13)
    d1 = delta(base)
    d2 = delta(d1)
    return np.concatenate([base, d1, d2], axis=-1)  # (T, 39)


def mfcc_batch(wavs: np.ndarray) -> np.ndarray:
    """(B, S) -> (B, T, 39)."""
    return np.stack([mfcc_single(w) for w in wavs])
