"""Layer-1 Pallas kernel: batched pairwise DTW over MFCC segment tiles.

The MAHC hot-spot is the pairwise DTW distance matrix: each subset of N
segments needs N(N-1)/2 alignments between variable-length sequences of
39-dimensional MFCC vectors.  This kernel computes one *tile* of that
matrix — all (bx, by) pair distances between a block of X segments and a
block of Y segments — in a single pallas_call.

Hardware adaptation (paper ran scalar CPU DTW; see DESIGN.md
§Hardware-Adaptation):

  * Local frame distances use the matmul identity
    ||x - y||^2 = ||x||^2 + ||y||^2 - 2 x.y  so the dominant O(T^2 D)
    term is a single (bx*T, D) x (D, by*T) contraction that targets the
    MXU systolic array.
  * The DP recurrence runs in anti-diagonal *wavefront* order: 2T-1
    steps, each updating a (bx, by, T) diagonal buffer fully vectorised
    on the VPU — the Pallas analogue of the threadblock-per-pair GPU
    soft-DTW layout.
  * BlockSpec tiles X/Y into VMEM; the (bx, by, T, T) local-cost tensor
    plus two diagonal carry buffers stay resident per grid cell.

interpret=True throughout: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode pallas lowers to plain HLO, which the
Rust `xla`-crate client then runs at XLA-CPU speed.

Semantics (shared with kernels/ref.py, the pure-numpy oracle):

  * monotone step set {(1,0), (0,1), (1,1)}, no slope weighting;
  * local distance = Euclidean (sqrt of squared distance);
  * cost accumulated from cell (0,0) to (lx-1, ly-1);
  * returned distance = accumulated cost / (lx + ly)  (path-length
    normalisation, standard for comparing variable-length segments);
  * optional Sakoe-Chiba band: cells with |i - j| > band are forbidden.

Padding beyond (lx, ly) never corrupts the result: a monotone path to
(lx-1, ly-1) only visits cells with i < lx and j < ly, so padded frames
are unreachable; masking only has to handle the *diagonal buffers* and
the final gather.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# A large-but-finite stand-in for +inf inside the DP.  Using actual inf
# risks inf - inf = nan under some fused rewrites; 1e30 survives every
# min/add in f32 without overflow for any realistic T.
BIG = 1.0e30


def _dtw_kernel(x_ref, y_ref, lenx_ref, leny_ref, out_ref, *, t_max: int, band: int | None):
    """Pallas kernel body: one (bx, by) tile of pairwise DTW distances.

    x_ref:    (bx, T, D) f32  — X segment block (VMEM)
    y_ref:    (by, T, D) f32  — Y segment block (VMEM)
    lenx_ref: (bx,)      i32  — true frame counts of X segments
    leny_ref: (by,)      i32  — true frame counts of Y segments
    out_ref:  (bx, by)   f32  — normalised DTW distances
    """
    x = x_ref[...]  # (bx, T, D)
    y = y_ref[...]  # (by, T, D)
    lenx = lenx_ref[...]  # (bx,)
    leny = leny_ref[...]  # (by,)

    bx, t, _d = x.shape
    by = y.shape[0]

    # ---- local distances via the MXU-friendly matmul identity --------
    # cross[p, i, q, j] = x[p, i] . y[q, j]; contraction over D.
    xsq = jnp.sum(x * x, axis=-1)  # (bx, T)
    ysq = jnp.sum(y * y, axis=-1)  # (by, T)
    x2 = x.reshape(bx * t, -1)
    y2 = y.reshape(by * t, -1)
    cross = jnp.dot(x2, y2.T, preferred_element_type=jnp.float32)  # (bx*T, by*T)
    cross = cross.reshape(bx, t, by, t)
    sq = (
        xsq[:, :, None, None] + ysq[None, None, :, :] - 2.0 * cross
    )  # (bx, T, by, T)
    # Clamp tiny negatives from cancellation before sqrt.
    local = jnp.sqrt(jnp.maximum(sq, 0.0))  # (bx, T, by, T)
    # Reorder to (bx, by, T_i, T_j) for the wavefront.
    local = jnp.transpose(local, (0, 2, 1, 3))

    if band is not None:
        ii = jnp.arange(t)[:, None]
        jj = jnp.arange(t)[None, :]
        local = jnp.where(jnp.abs(ii - jj) > band, BIG, local)

    # ---- anti-diagonal wavefront DP ----------------------------------
    # Buffers indexed by row i; diagonal k holds cells (i, k-i).
    idx = jnp.arange(t)  # candidate i values
    # Per-pair end coordinates.
    end_k = lenx[:, None] + leny[None, :] - 2  # (bx, by) diag of the end cell
    end_i = jnp.broadcast_to(lenx[:, None] - 1, (bx, by))  # row of the end cell

    def shift_down(buf):
        # buf[..., i] -> buf[..., i-1] with BIG at i=0 (row -1 is invalid).
        return jnp.concatenate(
            [jnp.full(buf.shape[:-1] + (1,), BIG, buf.dtype), buf[..., :-1]], axis=-1
        )

    def step(k, carry):
        prev, prev2, acc = carry  # prev = diag k-1, prev2 = diag k-2
        j = k - idx  # (T,) column per candidate row
        valid = (j >= 0) & (j < t)  # cells actually on diagonal k
        jc = jnp.clip(j, 0, t - 1)
        # Gather local[., ., i, k-i] for every row i: advanced indexing
        # stays vectorised over the (bx, by) pair axes.
        dk = local[:, :, idx, jc]  # (bx, by, T)
        dk = jnp.where(valid[None, None, :], dk, BIG)

        up = prev  # C[i, j-1]   (diag k-1, same row)
        left = shift_down(prev)  # C[i-1, j]   (diag k-1, row above)
        diag = shift_down(prev2)  # C[i-1, j-1] (diag k-2, row above)
        pred = jnp.minimum(jnp.minimum(up, left), diag)
        # Origin cell (0, 0) has no predecessor: cost is just d[0,0].
        pred = jnp.where((k == 0) & (idx == 0)[None, None, :], 0.0, pred)
        cur = jnp.where(valid[None, None, :], dk + pred, BIG)
        cur = jnp.minimum(cur, BIG)  # keep padded lanes finite

        # Harvest the end-cell value on the diagonal where it lives.
        hit = end_k == k  # (bx, by)
        val = jnp.take_along_axis(cur, end_i[..., None], axis=-1)[..., 0]
        acc = jnp.where(hit, val, acc)
        return cur, prev, acc

    init = (
        jnp.full((bx, by, t), BIG, jnp.float32),
        jnp.full((bx, by, t), BIG, jnp.float32),
        jnp.full((bx, by), BIG, jnp.float32),
    )
    _, _, acc = jax.lax.fori_loop(0, 2 * t - 1, step, init)

    norm = (lenx[:, None] + leny[None, :]).astype(jnp.float32)
    out_ref[...] = acc / norm


def dtw_tile(
    x: jax.Array,
    y: jax.Array,
    lenx: jax.Array,
    leny: jax.Array,
    *,
    block_x: int | None = None,
    block_y: int | None = None,
    band: int | None = None,
) -> jax.Array:
    """Pairwise DTW distances between two padded segment batches.

    x:    (Bx, T, D) f32 — padded MFCC segments
    y:    (By, T, D) f32
    lenx: (Bx,) i32 — true lengths (1 <= lenx <= T)
    leny: (By,) i32
    band: optional Sakoe-Chiba band radius (cells |i-j| > band forbidden)

    Returns (Bx, By) f32 of path-length-normalised DTW distances.
    """
    bx_total, t, d = x.shape
    by_total = y.shape[0]
    bx = block_x or bx_total
    by = block_y or by_total
    if bx_total % bx or by_total % by:
        raise ValueError(f"batch ({bx_total},{by_total}) not divisible by block ({bx},{by})")

    grid = (bx_total // bx, by_total // by)
    kernel = functools.partial(_dtw_kernel, t_max=t, band=band)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bx, t, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((by, t, d), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bx,), lambda i, j: (i,)),
            pl.BlockSpec((by,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bx, by), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bx_total, by_total), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, y, lenx, leny)
