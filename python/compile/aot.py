"""AOT lowering: JAX/Pallas graphs -> HLO text artifacts for the Rust runtime.

Run once at build time (`make artifacts`); Python is never on the
request path.  Each exported graph becomes one `artifacts/<name>.hlo.txt`
plus an entry in `artifacts/manifest.json` describing its input/output
shapes, which rust/src/runtime/ parses to plan tiling and marshalling.

Interchange format is HLO *text*, NOT `lowered.compile()` /
`.serialize()` protos: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published
`xla` 0.1.6 crate binds) rejects (`proto.id() <= INT_MAX`).  The text
parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md.

All graphs are lowered with return_tuple=True; the Rust side unwraps
with `to_tuple1()`.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

# Tile configurations exported for the Rust distance builder.  Sizes:
# the big tiles amortise dispatch overhead on large subsets; the small
# tile bounds padding waste for subset remainders and the medoid stage.
# Two T buckets: wavefront steps scale with 2T-1 and the local-distance
# matmul with T², so requests whose longest segment fits T=32 run ~3x
# cheaper through the T=32 variant (runtime picks per request).
DTW_TILES = [
    # (bx_total, by_total, block, T, D)
    (32, 32, 32, 64, 39),
    (32, 32, 32, 32, 39),
    (8, 8, 8, 64, 39),
]
# Sakoe-Chiba banded variant for the ablation bench (band radius in frames).
DTW_BAND_TILES = [
    (32, 32, 16, 64, 39, 16),
]
# MFCC front-end batch: S = 5200 samples (325 ms) -> exactly T = 64 frames,
# matching the DTW tile's time bucket.
MFCC_BATCHES = [
    (16, 5200),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    print_large_constants=True is load-bearing: the default printer
    elides big array literals as `{...}`, which the consuming parser
    silently reads back as zeros — the MFCC graph's Hamming window and
    mel/DCT matrices would vanish (caught by the rust
    artifact_crosscheck integration test).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_dtw(bx, by, block, t, d, band=None):
    def graph(x, y, lenx, leny):
        from .kernels import dtw as dtw_kernel

        return (
            dtw_kernel.dtw_tile(
                x, y, lenx, leny, block_x=min(block, bx), block_y=min(block, by), band=band
            ),
        )

    specs = (
        jax.ShapeDtypeStruct((bx, t, d), jnp.float32),
        jax.ShapeDtypeStruct((by, t, d), jnp.float32),
        jax.ShapeDtypeStruct((bx,), jnp.int32),
        jax.ShapeDtypeStruct((by,), jnp.int32),
    )
    return jax.jit(graph).lower(*specs)


def lower_mfcc(b, s):
    wav_spec = jax.ShapeDtypeStruct((b, s), jnp.float32)
    len_spec = jax.ShapeDtypeStruct((b,), jnp.int32)
    return jax.jit(model.mfcc_frontend).lower(wav_spec, len_spec)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    manifest = {"format": "hlo-text", "entries": []}

    for bx, by, block, t, d in DTW_TILES:
        name = f"dtw_b{bx}x{by}_t{t}_d{d}"
        text = to_hlo_text(lower_dtw(bx, by, block, t, d))
        path = os.path.join(args.outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "kind": "dtw",
                "bx": bx,
                "by": by,
                "t": t,
                "d": d,
                "band": None,
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    for bx, by, block, t, d, band in DTW_BAND_TILES:
        name = f"dtw_b{bx}x{by}_t{t}_d{d}_band{band}"
        text = to_hlo_text(lower_dtw(bx, by, block, t, d, band=band))
        path = os.path.join(args.outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "kind": "dtw",
                "bx": bx,
                "by": by,
                "t": t,
                "d": d,
                "band": band,
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    for b, s in MFCC_BATCHES:
        t_out = model.mfcc_num_frames(s)
        name = f"mfcc_b{b}_s{s}"
        text = to_hlo_text(lower_mfcc(b, s))
        path = os.path.join(args.outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "kind": "mfcc",
                "b": b,
                "s": s,
                "t_out": t_out,
                "feat": 39,
                "frame_len": ref.FRAME_LEN,
                "frame_hop": ref.FRAME_HOP,
                "sample_rate": ref.SAMPLE_RATE,
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.outdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['entries'])} entries)")


if __name__ == "__main__":
    main()
