"""Kernel-vs-oracle: the CORE correctness signal for Layer 1.

The Pallas wavefront DTW (compile/kernels/dtw.py) is asserted against
the plain-loop numpy oracle (compile/kernels/ref.py) over hypothesis-
driven sweeps of shapes, lengths, dtypes and content.  Distinct shapes
force re-trace + re-compile, so hypothesis draws from a bounded shape
pool and spends its examples on data/length variation.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dtw, ref

# Shapes small enough for the O(T^2) loop oracle, varied enough to hit
# even/odd T, D=1, non-square tiles and block-divided grids.
SHAPE_POOL = [
    # (bx, by, t, d, block_x, block_y)
    (1, 1, 4, 1, None, None),
    (2, 3, 8, 2, None, None),
    (4, 4, 12, 3, 2, 2),
    (3, 5, 7, 4, None, None),
    (4, 2, 16, 39, 2, 2),
    (6, 6, 10, 5, 3, 3),
]


def _case(rng, bx, by, t, d, lo=1):
    x = rng.normal(size=(bx, t, d)).astype(np.float32)
    y = rng.normal(size=(by, t, d)).astype(np.float32)
    lx = rng.integers(lo, t + 1, size=bx).astype(np.int32)
    ly = rng.integers(lo, t + 1, size=by).astype(np.int32)
    return x, y, lx, ly


def _run(x, y, lx, ly, **kw):
    return np.asarray(
        dtw.dtw_tile(jnp.asarray(x), jnp.asarray(y), jnp.asarray(lx), jnp.asarray(ly), **kw)
    )


@settings(max_examples=30, deadline=None)
@given(shape=st.sampled_from(SHAPE_POOL), seed=st.integers(0, 2**31 - 1))
def test_kernel_matches_oracle(shape, seed):
    bx, by, t, d, blk_x, blk_y = shape
    rng = np.random.default_rng(seed)
    x, y, lx, ly = _case(rng, bx, by, t, d)
    got = _run(x, y, lx, ly, block_x=blk_x, block_y=blk_y)
    want = ref.dtw_pairwise(x, y, lx, ly)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), band=st.sampled_from([1, 3, 6]))
def test_kernel_banded_matches_oracle(seed, band):
    rng = np.random.default_rng(seed)
    x, y, lx, ly = _case(rng, 4, 4, 12, 3)
    got = _run(x, y, lx, ly, band=band)
    want = ref.dtw_pairwise(x, y, lx, ly, band=band)
    feasible = np.isfinite(want)
    np.testing.assert_allclose(got[feasible], want[feasible], rtol=1e-4, atol=1e-5)
    # Infeasible pairs (|lx-ly| > band) surface as huge sentinels, which
    # the Rust side maps back to "no path".
    assert np.all(got[~feasible] > 1e20 / 64)


def test_identical_segments_zero_distance():
    """Self-distance is ~0.  Not exactly 0: the kernel computes
    ||x-y||^2 = ||x||^2 + ||y||^2 - 2x.y (the MXU-friendly identity),
    which leaves O(eps*||x||^2) cancellation noise that sqrt amplifies
    to ~1e-3 near zero — negligible against O(1) inter-class distances
    (see DESIGN.md §Hardware-Adaptation)."""
    rng = np.random.default_rng(7)
    x = rng.normal(size=(3, 10, 4)).astype(np.float32)
    lx = np.array([10, 6, 3], dtype=np.int32)
    got = _run(x, x, lx, lx)
    assert np.allclose(np.diag(got), 0.0, atol=5e-3)


def test_symmetry():
    rng = np.random.default_rng(8)
    x, y, lx, ly = _case(rng, 4, 4, 9, 3)
    a = _run(x, y, lx, ly)
    b = _run(y, x, ly, lx)
    np.testing.assert_allclose(a, b.T, rtol=1e-5, atol=1e-6)


def test_nonnegative():
    rng = np.random.default_rng(9)
    x, y, lx, ly = _case(rng, 5, 5, 11, 2)
    assert np.all(_run(x, y, lx, ly) >= 0.0)


def test_length_one_segments():
    """lx = ly = 1 reduces to the frame distance / 2."""
    rng = np.random.default_rng(10)
    x = rng.normal(size=(2, 6, 3)).astype(np.float32)
    y = rng.normal(size=(2, 6, 3)).astype(np.float32)
    ones = np.ones(2, dtype=np.int32)
    got = _run(x, y, ones, ones)
    want = np.zeros((2, 2))
    for p in range(2):
        for q in range(2):
            want[p, q] = np.linalg.norm(x[p, 0] - y[q, 0]) / 2.0
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_padding_is_ignored():
    """Garbage in padded frames must not change the result."""
    rng = np.random.default_rng(11)
    x, y, lx, ly = _case(rng, 3, 3, 10, 3)
    base = _run(x, y, lx, ly)
    x2, y2 = x.copy(), y.copy()
    for p in range(3):
        x2[p, lx[p]:] = 1e6
        y2[p, ly[p]:] = -1e6
    np.testing.assert_allclose(_run(x2, y2, lx, ly), base, rtol=1e-5, atol=1e-6)


def test_triangle_inequality_tendency():
    """Normalised DTW is not a metric, but on well-separated point-like
    segments (each frame ~ constant) it reduces to scaled Euclidean
    distance, where the triangle inequality must hold."""
    rng = np.random.default_rng(12)
    centers = rng.normal(size=(3, 1, 4)).astype(np.float32) * 5
    segs = np.repeat(centers, 8, axis=1)  # (3, 8, 4) constant sequences
    lens = np.full(3, 8, dtype=np.int32)
    d = _run(segs, segs, lens, lens)
    for i in range(3):
        for j in range(3):
            for k in range(3):
                assert d[i, j] <= d[i, k] + d[k, j] + 1e-5


def test_monotone_under_scaling():
    """Scaling all features by a > 1 scales distances by a (homogeneity)."""
    rng = np.random.default_rng(13)
    x, y, lx, ly = _case(rng, 3, 3, 9, 3)
    base = _run(x, y, lx, ly)
    scaled = _run(2.5 * x, 2.5 * y, lx, ly)
    np.testing.assert_allclose(scaled, 2.5 * base, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("t", [4, 5, 16, 64])
def test_full_length_various_t(t):
    rng = np.random.default_rng(t)
    x = rng.normal(size=(2, t, 3)).astype(np.float32)
    y = rng.normal(size=(2, t, 3)).astype(np.float32)
    full = np.full(2, t, dtype=np.int32)
    got = _run(x, y, full, full)
    want = ref.dtw_pairwise(x, y, full, full)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_block_grid_equals_single_block():
    rng = np.random.default_rng(14)
    x, y, lx, ly = _case(rng, 8, 8, 10, 3)
    whole = _run(x, y, lx, ly)
    tiled = _run(x, y, lx, ly, block_x=4, block_y=2)
    np.testing.assert_allclose(whole, tiled, rtol=1e-6, atol=1e-7)


def test_bad_block_raises():
    rng = np.random.default_rng(15)
    x, y, lx, ly = _case(rng, 4, 4, 6, 2)
    with pytest.raises(ValueError):
        _run(x, y, lx, ly, block_x=3)
