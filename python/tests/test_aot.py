"""AOT lowering sanity: every exported graph produces loadable HLO text
whose entry computation has the advertised shapes, and the lowered DTW
graph still matches the oracle when round-tripped through HLO.
"""

import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


def test_dtw_lowering_produces_hlo_text():
    lowered = aot.lower_dtw(8, 8, 8, 16, 4)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[8,8]" in text  # output tile
    assert "f32[8,16,4]" in text  # input block


def test_mfcc_lowering_produces_hlo_text():
    lowered = aot.lower_mfcc(2, 5200)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[2,64,39]" in text


def test_banded_variant_lowering():
    text = aot.to_hlo_text(aot.lower_dtw(8, 8, 8, 16, 4, band=4))
    assert "ENTRY" in text


def test_lowered_dtw_executes_and_matches_oracle():
    """Round-trip through the lowering path (compile via jax, execute)."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(8, 16, 4)).astype(np.float32)
    y = rng.normal(size=(8, 16, 4)).astype(np.float32)
    lx = rng.integers(1, 17, size=8).astype(np.int32)
    ly = rng.integers(1, 17, size=8).astype(np.int32)
    lowered = aot.lower_dtw(8, 8, 8, 16, 4)
    compiled = lowered.compile()
    (got,) = compiled(jnp.asarray(x), jnp.asarray(y), jnp.asarray(lx), jnp.asarray(ly))
    want = ref.dtw_pairwise(x, y, lx, ly)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)


def test_full_export_writes_manifest(tmp_path):
    """End-to-end aot.main() into a temp dir: all files + manifest present.

    Uses the small tile table only (monkeypatched) to keep the test fast.
    """
    import compile.aot as aot_mod

    old_tiles, old_band, old_mfcc = aot_mod.DTW_TILES, aot_mod.DTW_BAND_TILES, aot_mod.MFCC_BATCHES
    aot_mod.DTW_TILES = [(4, 4, 4, 8, 3)]
    aot_mod.DTW_BAND_TILES = []
    aot_mod.MFCC_BATCHES = [(1, 400)]
    argv = sys.argv
    sys.argv = ["aot", "--outdir", str(tmp_path)]
    try:
        aot_mod.main()
    finally:
        sys.argv = argv
        aot_mod.DTW_TILES, aot_mod.DTW_BAND_TILES, aot_mod.MFCC_BATCHES = (
            old_tiles,
            old_band,
            old_mfcc,
        )
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    assert len(manifest["entries"]) == 2
    for e in manifest["entries"]:
        p = tmp_path / e["file"]
        assert p.exists()
        assert "ENTRY" in p.read_text()


def test_manifest_schema_fields():
    """The Rust runtime depends on these exact manifest keys."""
    dtw_keys = {"name", "file", "kind", "bx", "by", "t", "d", "band"}
    mfcc_keys = {"name", "file", "kind", "b", "s", "t_out", "feat",
                 "frame_len", "frame_hop", "sample_rate"}
    # Exercised indirectly via aot.main() in the test above; here just pin
    # the tile tables so a rename breaks loudly.
    assert all(len(t) == 5 for t in aot.DTW_TILES)
    assert all(len(t) == 6 for t in aot.DTW_BAND_TILES)
    assert all(len(t) == 2 for t in aot.MFCC_BATCHES)
    assert dtw_keys and mfcc_keys
