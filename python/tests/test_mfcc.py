"""Layer-2 MFCC front-end vs the numpy oracle, plus signal-level sanity."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _mfcc(wav):
    b, samples = wav.shape
    t = model.mfcc_num_frames(samples)
    lens = jnp.full((b,), t, dtype=jnp.int32)
    return np.asarray(model.mfcc_frontend(jnp.asarray(wav.astype(np.float32)), lens)[0])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.sampled_from([1e-3, 0.1, 1.0]))
def test_matches_oracle_random_signals(seed, scale):
    rng = np.random.default_rng(seed)
    wav = (rng.normal(size=(2, 5200)) * scale).astype(np.float32)
    got = _mfcc(wav)
    want = ref.mfcc_batch(wav)
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


def test_output_shape():
    wav = np.zeros((4, 5200), dtype=np.float32)
    out = _mfcc(wav)
    assert out.shape == (4, 64, 39)
    assert model.mfcc_num_frames(5200) == 64


def test_silence_hits_floor():
    """All-zero input: log terms bottom out at log(FLOOR), deltas are 0."""
    out = _mfcc(np.zeros((1, 5200), dtype=np.float32))
    assert np.all(np.isfinite(out))
    np.testing.assert_allclose(out[0, :, 12], np.log(ref.FLOOR), rtol=1e-5)
    np.testing.assert_allclose(out[0, :, 13:], 0.0, atol=1e-5)


def test_pure_tone_energy_in_right_mel_band():
    """A 1 kHz tone concentrates filterbank energy near the 1 kHz filters."""
    t = np.arange(5200) / ref.SAMPLE_RATE
    wav = (0.5 * np.sin(2 * np.pi * 1000.0 * t)).astype(np.float32)[None, :]
    frames = ref.frame_signal(
        np.concatenate([[wav[0, 0] * (1 - ref.PREEMPH)], wav[0, 1:] - ref.PREEMPH * wav[0, :-1]])
    ) * ref.hamming()
    power = np.abs(np.fft.rfft(frames, n=ref.NFFT, axis=-1)) ** 2
    mel = power @ ref.mel_filterbank().T
    peak_filter = np.argmax(mel.mean(axis=0))
    centers = ref.mel_to_hz(
        np.linspace(ref.hz_to_mel(0), ref.hz_to_mel(ref.SAMPLE_RATE / 2), ref.N_MELS + 2)
    )[1:-1]
    assert abs(centers[peak_filter] - 1000.0) < 300.0


def test_deterministic():
    rng = np.random.default_rng(3)
    wav = rng.normal(size=(1, 5200)).astype(np.float32)
    np.testing.assert_array_equal(_mfcc(wav), _mfcc(wav))


def test_amplitude_invariance_of_shape():
    """Cepstra of a*x differ from cepstra of x only in c0/logE-like terms;
    since we keep c1..c12, scaling shifts logE but leaves MFCC deltas of
    spectral *shape* nearly unchanged."""
    rng = np.random.default_rng(4)
    wav = rng.normal(size=(1, 5200)).astype(np.float32)
    a = _mfcc(wav)
    b = _mfcc(4.0 * wav)
    # c1..c12 identical up to float noise (log power shifts cancel in DCT rows >= 1)
    np.testing.assert_allclose(a[0, :, :12], b[0, :, :12], rtol=1e-3, atol=1e-3)
    # logE shifted by log(16)
    np.testing.assert_allclose(b[0, :, 12] - a[0, :, 12], np.log(16.0), rtol=1e-3)


def test_delta_of_constant_is_zero():
    feat = np.tile(np.array([[1.0, -2.0, 3.0]]), (10, 1))
    np.testing.assert_allclose(ref.delta(feat), 0.0, atol=1e-12)


def test_delta_of_linear_ramp_is_slope():
    t = np.arange(20, dtype=np.float64)
    feat = (2.0 * t)[:, None]
    d = ref.delta(feat)
    # Interior frames: regression over a linear ramp returns the slope.
    np.testing.assert_allclose(d[2:-2, 0], 2.0, rtol=1e-12)


@pytest.mark.parametrize("n_samples,expect_t", [(160, 1), (240, 2), (5200, 64)])
def test_frame_count(n_samples, expect_t):
    assert model.mfcc_num_frames(n_samples) == expect_t


def test_partial_length_lane_matches_truncated_ref():
    """A lane whose waveform fills only part of the S bucket must produce
    (for its true frames) exactly what the oracle computes on the
    unpadded signal — i.e. deltas replicate the lane's own last real
    frame, not padded silence."""
    rng = np.random.default_rng(9)
    true_samples = 1040  # -> 12 frames
    t_true = model.mfcc_num_frames(true_samples)
    wav = np.zeros((2, 5200), dtype=np.float32)
    sig = (rng.normal(size=true_samples) * 0.3).astype(np.float32)
    wav[0, :true_samples] = sig
    lens = jnp.asarray([t_true, model.mfcc_num_frames(5200)], dtype=jnp.int32)
    got = np.asarray(model.mfcc_frontend(jnp.asarray(wav), lens)[0])
    want = ref.mfcc_single(sig)
    np.testing.assert_allclose(got[0, :t_true], want, rtol=5e-3, atol=5e-3)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), frames=st.integers(2, 64))
def test_random_partial_lengths_match_truncated_oracle(seed, frames):
    """Hypothesis sweep of the masked-delta path: any true frame count
    must reproduce the oracle on the unpadded signal."""
    rng = np.random.default_rng(seed)
    samples = 160 + (frames - 1) * 80
    wav = np.zeros((1, 5200), dtype=np.float32)
    sig = (rng.normal(size=samples) * 0.2).astype(np.float32)
    wav[0, :samples] = sig
    lens = jnp.asarray([frames], dtype=jnp.int32)
    got = np.asarray(model.mfcc_frontend(jnp.asarray(wav), lens)[0])
    want = ref.mfcc_single(sig)
    np.testing.assert_allclose(got[0, :frames], want, rtol=1e-2, atol=1e-2)
